"""Docs stay executable: run fenced snippets and check relative links.

CI's docs job runs this over README.md and docs/*.md (and DESIGN.md for
link-checking). Two guarantees:

1. **Snippets run.** Every fenced ```python or ```bash block is executed
   (python via a subprocess with PYTHONPATH=src:., bash via `bash -euo
   pipefail`) under REPRO_SMOKE=1, so a doc snippet that drifts from the
   API fails the build instead of lying to the reader. Blocks whose info
   string carries `no-run` (e.g. ```bash no-run) are skipped — use it for
   illustrative fragments and commands too slow or environment-bound for
   CI (installs, full bench runs); everything else must execute.
2. **Relative links resolve.** Every `[text](target)` whose target is not
   an absolute URL or a bare anchor must exist on disk relative to the doc
   (anchors on existing files are accepted without heading validation).

Usage: python tools/check_docs.py [files...]   (defaults to README.md,
DESIGN.md, docs/*.md; exits non-zero listing every failure).
"""
from __future__ import annotations

import os
import re
import subprocess
import sys
import tempfile
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

FENCE_RE = re.compile(r"^```(\S*)[ \t]*([^\n]*)$")
# [text](target) — skips image links' inner ! only in that it doesn't matter
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def extract_blocks(text: str):
    """Yield (lang, info, first_line_no, body) for every fenced block."""
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = FENCE_RE.match(lines[i])
        if m and m.group(1) != "":
            lang, info = m.group(1).lower(), m.group(2)
            body, start = [], i + 1
            i += 1
            while i < len(lines) and lines[i].rstrip() != "```":
                body.append(lines[i])
                i += 1
            yield lang, info, start + 1, "\n".join(body)
        i += 1


def run_block(lang: str, body: str) -> subprocess.CompletedProcess:
    env = dict(os.environ, REPRO_SMOKE="1", JAX_PLATFORMS="cpu")
    env["PYTHONPATH"] = f"src:.:{env.get('PYTHONPATH', '')}"
    suffix = ".py" if lang == "python" else ".sh"
    with tempfile.NamedTemporaryFile(
        "w", suffix=suffix, dir=ROOT, delete=False
    ) as f:
        f.write(body + "\n")
        path = f.name
    try:
        cmd = (
            [sys.executable, path] if lang == "python"
            else ["bash", "-euo", "pipefail", path]
        )
        return subprocess.run(
            cmd, cwd=ROOT, env=env, capture_output=True, text=True,
            timeout=600,
        )
    finally:
        os.unlink(path)


def check_links(doc: Path, text: str) -> list[str]:
    errors = []
    for target in LINK_RE.findall(text):
        if re.match(r"^[a-z][a-z0-9+.-]*:", target) or target.startswith("#"):
            continue  # URL scheme or in-page anchor
        rel = target.split("#", 1)[0]
        if not (doc.parent / rel).exists():
            errors.append(f"{doc}: broken relative link -> {target}")
    return errors


def check_doc(doc: Path) -> list[str]:
    text = doc.read_text()
    errors = check_links(doc, text)
    for lang, info, line, body in extract_blocks(text):
        if lang not in ("python", "bash", "sh"):
            continue
        if "no-run" in info.split():
            continue
        lang = "bash" if lang == "sh" else lang
        print(f"  running {doc}:{line} ({lang}, {len(body.splitlines())} "
              "lines)", flush=True)
        proc = run_block(lang, body)
        if proc.returncode != 0:
            errors.append(
                f"{doc}:{line}: {lang} snippet failed "
                f"(exit {proc.returncode})\n"
                f"--- stdout ---\n{proc.stdout}\n"
                f"--- stderr ---\n{proc.stderr}"
            )
    return errors


def main(argv=None) -> int:
    args = (argv if argv is not None else sys.argv[1:])
    docs = (
        [Path(a) for a in args] if args
        else [ROOT / "README.md", ROOT / "DESIGN.md",
              *sorted((ROOT / "docs").glob("*.md"))]
    )
    errors = []
    for doc in docs:
        print(f"checking {doc}", flush=True)
        errors.extend(check_doc(doc))
    for e in errors:
        print(f"FAIL: {e}")
    if not errors:
        print(f"{len(docs)} doc(s) clean: snippets run, links resolve")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
