"""Synthesize trace fixtures in the real formats core.traces reads.

CI has no network, and the paper's Wikipedia/Twitter traces are not
redistributable anyway — so benches and tests exercise the *ingestion path*
(format parsing, raw-key hashing, count expansion, chunk packing) on
fixtures this tool writes: same line formats, zipf-skewed key popularity,
fully deterministic for a (events, keys, z, seed) tuple.

Two formats, mirroring core.traces:

* ``wikipedia``: ``project page_title count bytes`` lines; the per-line
  count aggregates consecutive same-key events, so ``expand_counts=True``
  reading recovers exactly ``events`` routed events.
* ``kv``: ``key<TAB>timestamp`` lines, one event per line.

Usage (CLI)::

    python tools/make_trace.py --out /tmp/fixtures --events 100000 \
        --keys 5000 --z 1.4 --seed 0 [--gzip]

writes ``trace.wikipedia[.gz]`` and ``trace.kv[.gz]`` under --out and prints
their paths.  Benches import ``write_trace_fixture`` directly.
"""
from __future__ import annotations

import argparse
import gzip
import sys
from pathlib import Path

import numpy as np

_SRC = Path(__file__).resolve().parent.parent / "src"
if str(_SRC) not in sys.path:  # standalone runs without PYTHONPATH=src
    sys.path.insert(0, str(_SRC))

from repro.core.streams import zipf_probs  # noqa: E402

__all__ = ["synth_events", "write_trace_fixture"]


def synth_events(
    n_events: int, n_keys: int = 5000, z: float = 1.4, seed: int = 0
) -> np.ndarray:
    """Deterministic zipf-skewed key-index sequence for the fixture."""
    probs = zipf_probs(n_keys, z)
    rng = np.random.default_rng(seed)
    cdf = np.cumsum(probs)
    cdf[-1] = 1.0
    return np.searchsorted(cdf, rng.random(n_events), side="right").astype(np.int64)


def _open_out(path: Path):
    if path.suffix == ".gz":
        return gzip.open(path, "wt", encoding="utf-8")
    return open(path, "w", encoding="utf-8")


def write_trace_fixture(
    path,
    fmt: str,
    n_events: int,
    n_keys: int = 5000,
    z: float = 1.4,
    seed: int = 0,
) -> Path:
    """Write a fixture at ``path`` holding exactly ``n_events`` events.

    fmt="wikipedia": runs of consecutive equal keys collapse into one
    ``en Page_<i> <run_len> <bytes>`` line (so count expansion is actually
    exercised); fmt="kv": one ``word_<i>\\t<ts>`` line per event.  The event
    sequence a core.traces reader yields from the file equals
    ``synth_events(...)`` mapped through the format's key naming, in order.
    """
    path = Path(path)
    idx = synth_events(n_events, n_keys=n_keys, z=z, seed=seed)
    with _open_out(path) as f:
        if fmt == "wikipedia":
            # collapse consecutive-equal runs into counted lines
            if len(idx):
                bounds = np.flatnonzero(np.diff(idx)) + 1
                starts = np.concatenate([[0], bounds])
                ends = np.concatenate([bounds, [len(idx)]])
                for s, e in zip(starts, ends):
                    i, c = int(idx[s]), int(e - s)
                    f.write(f"en Page_{i} {c} {c * 4096}\n")
        elif fmt == "kv":
            for t, i in enumerate(idx):
                f.write(f"word_{int(i)}\t{t}\n")
        else:
            raise ValueError(f"unknown fixture format {fmt!r}")
    return path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", type=Path, default=Path("."), help="output dir")
    ap.add_argument("--events", type=int, default=100_000)
    ap.add_argument("--keys", type=int, default=5000)
    ap.add_argument("--z", type=float, default=1.4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--gzip", action="store_true", help="write .gz files")
    args = ap.parse_args(argv)
    args.out.mkdir(parents=True, exist_ok=True)
    ext = ".gz" if args.gzip else ""
    for fmt in ("wikipedia", "kv"):
        p = write_trace_fixture(
            args.out / f"trace.{fmt}{ext}", fmt, args.events,
            n_keys=args.keys, z=args.z, seed=args.seed,
        )
        print(p)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
