"""Serving demo: batched greedy generation from a small LM + the serving-edge
prefix-cache/balance tradeoff, measured by the discrete-event simulator.

  PYTHONPATH=src python examples/serve_demo.py [--scheduler w_choices]

Each scheduler routes the same skewed multi-tenant session stream across 50
replicas; the simulator drives request completions (so imbalance numbers are
over genuinely outstanding work), an LRU prefix cache per replica measures
hit-rate, and per-tenant SLO accounting counts violations.  W-Choices is the
default: cold sessions keep PoTC's <= 2-replica affinity, hot sessions trade
affinity for balance.

--queue-bound and --kill-at exercise the overload/failure surfaces;
--capacities gives replicas heterogeneous speeds (pattern tiled across the
pool — routing normalizes loads by capacity, the simulator serves at the
true rates; see docs/operator-guide.md).

REPRO_SMOKE=1 shrinks generation length and stream for CI's examples-smoke.
"""
import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, make_tiny
from repro.core.routing import DEFAULT_SCHEDULER, make_policy, scheduler_sweep_names
from repro.core.streams import multi_tenant_stream
from repro.models import init_params
from repro.serving import PolicyScheduler, ServeEngine, simulate_serving

SCHEDULERS = scheduler_sweep_names()

ap = argparse.ArgumentParser()
ap.add_argument("--scheduler", default=DEFAULT_SCHEDULER, choices=SCHEDULERS)
ap.add_argument("--queue-bound", type=int, default=None,
                help="bounded per-replica FIFO; overflow arrivals are shed")
ap.add_argument("--kill-at", type=float, default=None, metavar="FRAC",
                help="kill replica 0 after this fraction of the stream; its "
                     "pending work drains to the live replicas")
ap.add_argument("--capacities", default=None, metavar="C1,C2,...",
                help="per-replica speed pattern tiled across the pool "
                     "(e.g. '1,2,4'); routing goes capacity-normalized")
args = ap.parse_args()

SMOKE = os.environ.get("REPRO_SMOKE") == "1"
cfg = make_tiny(get_config("qwen2.5-3b"))
params = init_params(cfg, jax.random.PRNGKey(0))
engine = ServeEngine(cfg, params, max_len=24 if SMOKE else 48)

prompts = jnp.asarray(
    np.random.default_rng(0).integers(1, cfg.vocab_size, (4, 12)), jnp.int32
)
out = engine.generate(prompts, n_new=4 if SMOKE else 16)
print("generated:", out.shape)
for row in np.asarray(out):
    print("  ", row.tolist())

# --- the serving edge: hit-rate vs balance under hot-session skew ----------
n_replicas, n_tenants = 50, 4  # theta = d/50 keeps every tenant's head hot
m = 2_000 if SMOKE else 10_000
keys, tenants = multi_tenant_stream(
    m, n_tenants=n_tenants, n_keys=m // 20, z=1.6,
    weights=[4, 2, 1, 1], seed=1,
)
kill_schedule = None
if args.kill_at is not None:
    kill_schedule = [(args.kill_at * m / (0.7 * n_replicas), 0)]
capacities = None
if args.capacities is not None:
    pat = np.asarray([float(c) for c in args.capacities.split(",")])
    capacities = np.resize(pat, n_replicas)
print(
    f"\nrequest routing: {m} requests, {n_replicas} replicas, "
    f"{n_tenants} tenants, Zipf(1.6) sessions, SLO 0.1"
    + (f", queue-bound {args.queue_bound}" if args.queue_bound else "")
    + (f", kill replica 0 @ {args.kill_at:.0%}" if kill_schedule else "")
    + (f", capacities {args.capacities} tiled" if capacities is not None else "")
)
print(f"{'scheduler':>12s}  cache-hit  outstanding-imb  routed-imb  "
      "p99-lat   shed  SLO-viol  fanout")
for name in SCHEDULERS:
    sched = PolicyScheduler(make_policy(name, n_replicas, d=2, seed=0),
                            capacities=capacities)
    res = simulate_serving(
        sched, keys, tenants=tenants, utilization=0.7,
        cache_capacity=32, slo=0.1,
        queue_bound=args.queue_bound, kill_schedule=kill_schedule,
    )
    star = "*" if name == args.scheduler else " "
    print(
        f"{star}{name:>11s}  {res.hit_rate:9.3f}  "
        f"{res.outstanding_imbalance:15.4f}  {res.assign_imbalance:10.4f}  "
        f"{res.latency_p99:7.2f}  {res.shed:5d}  "
        f"{res.tenant_report['tenants_violating']:>5d}/{n_tenants}  "
        f"{res.session_fanout_max:6d}"
    )
    assert res.completed + res.shed == m  # zero lost completions
    assert sched.loads.sum() == 0.0  # completions drained the ledger

print(
    "\nW-Choices: near-KG cache hit-rate at near-RR balance — hot sessions "
    "split across\nreplicas (the paper's key splitting), cold sessions keep "
    "<= 2-replica affinity."
)
