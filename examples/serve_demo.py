"""Serving demo: batched greedy generation from a small LM + PKG-PoTC
request routing across replicas under hot-session skew.

  PYTHONPATH=src python examples/serve_demo.py

REPRO_SMOKE=1 shrinks generation length and stream for CI's examples-smoke.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, make_tiny
from repro.core.streams import zipf_stream
from repro.models import init_params
from repro.serving import KGScheduler, PoTCScheduler, RoundRobinScheduler, ServeEngine

SMOKE = os.environ.get("REPRO_SMOKE") == "1"
cfg = make_tiny(get_config("qwen2.5-3b"))
params = init_params(cfg, jax.random.PRNGKey(0))
engine = ServeEngine(cfg, params, max_len=24 if SMOKE else 48)

prompts = jnp.asarray(
    np.random.default_rng(0).integers(1, cfg.vocab_size, (4, 12)), jnp.int32
)
out = engine.generate(prompts, n_new=4 if SMOKE else 16)
print("generated:", out.shape)
for row in np.asarray(out):
    print("  ", row.tolist())

# --- replica routing under skewed session keys -----------------------------
print("\nrequest routing, 4 replicas, Zipf(1.2) session keys:")
keys = zipf_stream(1000 if SMOKE else 5000, 250, 1.2, seed=1)
for name, sched in [
    ("PoTC (PKG)", PoTCScheduler(4)),
    ("sticky KG", KGScheduler(4)),
    ("round-robin", RoundRobinScheduler(4)),
]:
    fanout = {}
    for k in keys:
        r = sched.route(int(k))
        fanout.setdefault(int(k), set()).add(r)
    loads = sched.loads
    mf = max(len(v) for v in fanout.values())
    print(
        f"  {name:12s} loads={loads.astype(int).tolist()} "
        f"imbalance={(loads.max()-loads.mean())/loads.sum():.4f} "
        f"max-replicas-per-session={mf}"
    )
print("\nPoTC: balanced like round-robin, but sessions stay on <=2 replicas")
print("(prefix caches stay warm) -- key splitting at the serving edge.")
