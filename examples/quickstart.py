"""Quickstart: PARTIAL KEY GROUPING in 30 lines.

Routes a skewed key stream to workers with KG / SG / PKG and prints the
imbalance each produces — the paper's core result, via the public API.

  PYTHONPATH=src python examples/quickstart.py

REPRO_SMOKE=1 shrinks the stream for CI's examples-smoke job.
"""
import os

import jax.numpy as jnp
import numpy as np

from repro.core import (
    avg_imbalance_fraction,
    hash_partition,
    keys_per_worker,
    pkg_partition,
    shuffle_partition,
    zipf_stream,
)

SMOKE = os.environ.get("REPRO_SMOKE") == "1"
W = 10  # workers (downstream PEIs)
n_msgs, n_keys = (20_000, 2_000) if SMOKE else (500_000, 50_000)
keys = zipf_stream(n_msgs=n_msgs, n_keys=n_keys, z=1.1, seed=0)
print(f"stream: {len(keys):,} messages, {len(np.unique(keys)):,} distinct keys")

for name, assign in [
    ("key grouping (hash)  ", hash_partition(jnp.asarray(keys), W)),
    ("shuffle grouping     ", shuffle_partition(jnp.asarray(keys), W)),
    ("PARTIAL KEY GROUPING ", pkg_partition(jnp.asarray(keys), W)),
]:
    a = np.asarray(assign)
    frac = avg_imbalance_fraction(a, W)
    mem = keys_per_worker(keys, a, W).sum()
    print(f"{name} imbalance fraction {frac:.2e}   total key-state {mem:,}")

print(
    "\nPKG: near-SG balance with at most 2x KG's key-state -- each key is"
    "\nsplit across its two hash choices, routed to the less loaded one."
)
