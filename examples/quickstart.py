"""Quickstart: PARTIAL KEY GROUPING in 30 lines.

Routes a skewed key stream to workers with KG / SG / PKG and prints the
imbalance each produces — the paper's core result, via the public API.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (
    avg_imbalance_fraction,
    hash_partition,
    keys_per_worker,
    pkg_partition,
    shuffle_partition,
    zipf_stream,
)

W = 10  # workers (downstream PEIs)
keys = zipf_stream(n_msgs=500_000, n_keys=50_000, z=1.1, seed=0)
print(f"stream: {len(keys):,} messages, {len(np.unique(keys)):,} distinct keys")

for name, assign in [
    ("key grouping (hash)  ", hash_partition(jnp.asarray(keys), W)),
    ("shuffle grouping     ", shuffle_partition(jnp.asarray(keys), W)),
    ("PARTIAL KEY GROUPING ", pkg_partition(jnp.asarray(keys), W)),
]:
    a = np.asarray(assign)
    frac = avg_imbalance_fraction(a, W)
    mem = keys_per_worker(keys, a, W).sum()
    print(f"{name} imbalance fraction {frac:.2e}   total key-state {mem:,}")

print(
    "\nPKG: near-SG balance with at most 2x KG's key-state -- each key is"
    "\nsplit across its two hash choices, routed to the less loaded one."
)
