"""End-to-end driver: train a ~100M-param LM for a few hundred steps on the
PKG-balanced data pipeline, with checkpointing and restart-on-failure.

  PYTHONPATH=src python examples/train_lm.py                  # ~100M, 200 steps
  PYTHONPATH=src python examples/train_lm.py --small --steps 60   # CI-sized

The --small variant uses the tiny qwen config; the default builds a 12-layer
d=768 model (~110M params with the 32k vocab) — a real training run on CPU
takes a while; both paths exercise the identical framework stack.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import TrainConfig, get_config, make_tiny
from repro.configs.base import ModelConfig
from repro.data import PKGDataPipeline, SyntheticCorpus
from repro.models import init_params
from repro.optim import adamw_init
from repro.train import TrainingHarness, make_train_step


def model_100m() -> ModelConfig:
    return ModelConfig(
        name="lm-100m",
        family="dense",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=4,
        head_dim=64,
        d_ff=2048,
        vocab_size=32_000,
        attn_pattern=("global",),
        tie_embeddings=True,
        attn_q_block=128,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.small:
        cfg = make_tiny(get_config("qwen2.5-3b"))
        steps = args.steps or 60
        batch, seq = args.batch or 8, args.seq or 128
    else:
        cfg = model_100m()
        steps = args.steps or 200
        batch, seq = args.batch or 8, args.seq or 512

    # small-batch from-scratch regime: higher LR so the unigram structure is
    # learned within a few hundred steps
    tcfg = TrainConfig(
        learning_rate=1.5e-3, total_steps=steps, warmup_steps=max(steps // 10, 2)
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"model {cfg.name}: {n/1e6:.1f}M params; {steps} steps of {batch}x{seq}")

    pipe = PKGDataPipeline(
        batch_size=batch, seq_len=seq, vocab_size=cfg.vocab_size,
        corpus=SyntheticCorpus(cfg.vocab_size, n_keys=8192, mean_len=seq, seed=1),
        partitioner="pkg", seed=1,
    )
    manager = CheckpointManager(args.ckpt_dir, keep=2)
    harness = TrainingHarness(
        jax.jit(make_train_step(cfg, tcfg)), pipe, manager,
        checkpoint_every=max(steps // 4, 10),
    )
    t0 = time.time()
    params, opt, hist = harness.run(params, adamw_init(params), steps, log_every=10)
    dt = time.time() - t0
    tok_s = steps * batch * seq / dt
    print(
        f"finished in {dt:.0f}s ({tok_s:,.0f} tok/s); "
        f"loss {hist[0]:.3f} -> {hist[-1]:.3f}"
    )
    assert hist[-1] < hist[0], "loss should decrease"


if __name__ == "__main__":
    main()
