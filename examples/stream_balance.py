"""The paper's evaluation in one script: imbalance across techniques,
datasets, and worker counts, with local vs global load estimation — a
condensed Table 2 + Fig 4 you can eyeball.

  PYTHONPATH=src python examples/stream_balance.py

REPRO_SMOKE=1 shrinks the dataset scale for CI's examples-smoke job.
"""
import os

import jax.numpy as jnp
import numpy as np

from repro.core import (
    PAPER_DATASETS,
    avg_imbalance_fraction,
    hash_partition,
    off_greedy_partition,
    on_greedy_partition,
    pkg_partition,
    potc_static_partition,
    simulate_sources,
)

W = 10
SCALE = 0.001 if os.environ.get("REPRO_SMOKE") == "1" else 0.005
print(f"{'dataset':8s} {'method':12s} imbalance-fraction")
for tag in ("WP", "CT", "LN1", "LN2"):
    keys = PAPER_DATASETS[tag].generate(seed=0, scale=SCALE)
    n_keys = int(keys.max()) + 1
    ks = jnp.asarray(keys)
    rows = {
        "hashing(KG)": np.asarray(hash_partition(ks, W)),
        "PoTC": np.asarray(potc_static_partition(ks, W, n_keys)),
        "On-Greedy": np.asarray(on_greedy_partition(ks, W, n_keys)),
        "Off-Greedy": np.asarray(off_greedy_partition(ks, W, n_keys)),
        "PKG": np.asarray(pkg_partition(ks, W)),
        "PKG-L5": simulate_sources(keys, W, n_sources=5, mode="local"),
    }
    for name, a in rows.items():
        print(f"{tag:8s} {name:12s} {avg_imbalance_fraction(a, W):.3e}")
    print()
