"""The paper's evaluation in one script: imbalance across techniques,
datasets, and worker counts, with local vs global load estimation — a
condensed Table 2 + Fig 4 you can eyeball.

  PYTHONPATH=src python examples/stream_balance.py

--shards N adds the multi-device sharded router (parallel/sharded_router.py)
rows: the same streams routed over an N-way ("data",) mesh with load-sync
epochs every --sync-period blocks.  Run under
XLA_FLAGS=--xla_force_host_platform_device_count=8 for real devices; with
fewer devices the bit-exact single-device emulation is used (same
assignments, flagged in the row name).

REPRO_SMOKE=1 shrinks the dataset scale for CI's examples-smoke job.
"""
import argparse
import os

import jax.numpy as jnp
import numpy as np

from repro.core import (
    PAPER_DATASETS,
    avg_imbalance_fraction,
    hash_partition,
    off_greedy_partition,
    on_greedy_partition,
    pkg_partition,
    pkg_sharded_partition,
    potc_static_partition,
    simulate_sources,
    w_choices_sharded_partition,
)

ap = argparse.ArgumentParser(description=__doc__)
ap.add_argument("--shards", type=int, default=1,
                help="route on the sharded router over this many shards")
ap.add_argument("--sync-period", type=int, default=4,
                help="blocks between load-sync epochs (with --shards > 1)")
args = ap.parse_args()

W = 10
SCALE = 0.001 if os.environ.get("REPRO_SMOKE") == "1" else 0.005
if args.shards > 1:
    import jax

    emulated = args.shards > jax.local_device_count()
    tag_s = f"-S{args.shards}" + ("(emu)" if emulated else "")
print(f"{'dataset':8s} {'method':16s} imbalance-fraction")
for tag in ("WP", "CT", "LN1", "LN2"):
    keys = PAPER_DATASETS[tag].generate(seed=0, scale=SCALE)
    n_keys = int(keys.max()) + 1
    ks = jnp.asarray(keys)
    rows = {
        "hashing(KG)": np.asarray(hash_partition(ks, W)),
        "PoTC": np.asarray(potc_static_partition(ks, W, n_keys)),
        "On-Greedy": np.asarray(on_greedy_partition(ks, W, n_keys)),
        "Off-Greedy": np.asarray(off_greedy_partition(ks, W, n_keys)),
        "PKG": np.asarray(pkg_partition(ks, W)),
        "PKG-L5": simulate_sources(keys, W, n_sources=5, mode="local"),
    }
    if args.shards > 1:
        rows[f"PKG{tag_s}"] = np.asarray(pkg_sharded_partition(
            ks, W, n_shards=args.shards, sync_period=args.sync_period))
        rows[f"W{tag_s}"] = np.asarray(w_choices_sharded_partition(
            ks, W, n_shards=args.shards, sync_period=args.sync_period))
    for name, a in rows.items():
        print(f"{tag:8s} {name:16s} {avg_imbalance_fraction(a, W):.3e}")
    print()
