"""Ablation: PKG-PoTC MoE routing vs vanilla top-k + aux loss, end to end.

Trains two tiny mixtral-family models (identical init/data) and reports loss
curves and per-expert load spread — the paper's balance guarantee as a
drop-in MoE router.

  PYTHONPATH=src python examples/moe_ablation.py [--steps 60]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig, get_config, make_tiny
from repro.data import PKGDataPipeline, SyntheticCorpus
from repro.models import init_params
from repro.models.moe import expert_load_stats, route
from repro.optim import adamw_init
from repro.train import make_train_step


def run(router: str, steps: int):
    cfg = dataclasses.replace(make_tiny(get_config("mixtral-8x7b")), router=router)
    tcfg = TrainConfig(learning_rate=2e-3, total_steps=steps, warmup_steps=5)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    pipe = PKGDataPipeline(
        batch_size=8, seq_len=64, vocab_size=cfg.vocab_size,
        corpus=SyntheticCorpus(cfg.vocab_size, n_keys=256, seed=7), seed=7,
    )
    step = jax.jit(make_train_step(cfg, tcfg))
    losses = []
    batch = None
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        params, opt, m = step(params, opt, batch, jnp.int32(i))
        losses.append(float(m["loss"]))
    # final expert balance on a fresh batch
    x = jax.random.normal(jax.random.PRNGKey(1), (2048, cfg.d_model))
    # use the first MoE layer's router weights
    layer = jax.tree_util.tree_map(lambda a: a[0], params["superblocks"][0])
    idx, _, _ = route(layer["mlp"], x, cfg)
    _, maxload = expert_load_stats(idx, cfg.n_experts)
    return losses, float(maxload)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()
    print(f"{'router':10s} {'loss[0:5]':>10s} {'loss[-5:]':>10s} {'max/mean expert load':>22s}")
    for router in ("topk_aux", "pkg_potc"):
        losses, maxload = run(router, args.steps)
        print(
            f"{router:10s} {np.mean(losses[:5]):10.4f} {np.mean(losses[-5:]):10.4f} "
            f"{maxload:22.2f}"
        )
    print("\nPKG-PoTC: comparable loss, structurally bounded expert load,")
    print("no auxiliary loss term to tune.")


if __name__ == "__main__":
    main()
