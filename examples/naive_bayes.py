"""The paper's running example (§2): streaming naïve Bayes with PKG.

A stream of (document, class) pairs feeds word-class counters partitioned
across W workers.  KG balances badly under the Zipf word law; SG balances but
every worker may hold every word (W× state, W-way merges); PKG balances like
SG while splitting each word across at most 2 workers, and the merged model
is *exactly* the sequential one (counters are a monoid).

  PYTHONPATH=src python examples/naive_bayes.py

REPRO_SMOKE=1 shrinks the corpus for CI's examples-smoke job.
"""
import os

import jax.numpy as jnp
import numpy as np

from repro.core import hash_partition, pkg_partition, shuffle_partition
from repro.core.applications import StreamingNaiveBayes
from repro.core.streams import zipf_probs

rng = np.random.default_rng(0)
VOCAB, CLASSES, DOCS, W = 5_000, 3, 2_000, 10
if os.environ.get("REPRO_SMOKE") == "1":
    VOCAB, DOCS = 1_000, 200

# class-conditional Zipf vocabularies with distinct hot words
base = zipf_probs(VOCAB, 1.05)
perms = [rng.permutation(VOCAB) for _ in range(CLASSES)]
docs, labels = [], []
for _ in range(DOCS):
    c = int(rng.integers(CLASSES))
    words = perms[c][np.searchsorted(np.cumsum(base), rng.random(30))]
    docs.append(words.astype(np.int32))
    labels.append(c)
flat = np.concatenate(docs)
flat_labels = np.concatenate([[l] * len(d) for d, l in zip(docs, labels)])
print(f"{len(docs)} docs, {len(flat):,} word occurrences, vocab {VOCAB}")

ref = StreamingNaiveBayes(CLASSES)
for d, l in zip(docs, labels):
    ref.observe(d, l)

print(f"\n{'scheme':8s} {'imbalance':>10s} {'counters':>9s} {'max workers/word':>17s} {'model==seq':>11s}")
for name, assign in [
    ("KG", np.asarray(hash_partition(jnp.asarray(flat), W))),
    ("SG", np.asarray(shuffle_partition(jnp.asarray(flat), W))),
    ("PKG", np.asarray(pkg_partition(jnp.asarray(flat), W))),
]:
    workers = [StreamingNaiveBayes(CLASSES) for _ in range(W)]
    for w, word, lab in zip(assign, flat, flat_labels):
        key = (int(word), int(lab))
        workers[w].word_class[key] = workers[w].word_class.get(key, 0) + 1
        workers[w].class_counts[lab] += 1
    merged = StreamingNaiveBayes(CLASSES)
    for w in workers:
        merged.merge_counts(w)
    loads = np.bincount(assign, minlength=W)
    frac = (loads.max() - loads.mean()) / len(flat)
    counters = sum(w.n_counters() for w in workers)
    per_word: dict[int, set] = {}
    for w, word in zip(assign, flat):
        per_word.setdefault(int(word), set()).add(int(w))
    fan = max(len(v) for v in per_word.values())
    exact = merged.word_class == ref.word_class
    print(f"{name:8s} {frac:10.2e} {counters:9,d} {fan:17d} {str(exact):>11s}")

test = perms[2][np.searchsorted(np.cumsum(base), rng.random(30))].astype(np.int32)
print(f"\nsample prediction (true class 2): ref={ref.predict(test, VOCAB)}")
print("PKG: SG-level balance, exact model, <=2 workers per word (2x key state).")
