"""Beyond-paper: heterogeneous worker capacities and elastic rescaling.

PKG assumes identical workers; real clusters mix machine generations and
autoscale.  arXiv 1705.09073 extends the Greedy-d argmin to *capacity-
normalized* loads (least ``load/c`` wins), which this repo threads end to
end — LoadLedger, every host partitioner, the Pallas route_block core, and
the sharded router (see DESIGN.md).  This bench gates that the weighting
actually pays, and that the serving simulator's autoscaler rescales cleanly:

* ``hetero_cap124`` — a {1x, 2x, 4x} worker pool.  Each partitioner routes
  the same zipf stream twice, with and without the capacity vector; the
  metric is the relative capacity-normalized imbalance
  (core.metrics.capacity_imbalance_fraction — 0 when work is exactly
  proportional to capacity).  Gates: capacity-weighted W-Choices beats its
  unweighted self by a wide margin and its fast workers genuinely absorb
  proportionally more work; weighted PKG is gated *no worse* only — its
  head key is pinned to a fixed hash-chosen d=2 candidate pair, so when
  that pair lands on slow workers no amount of load weighting can move it
  (the exact limitation W-Choices lifts by freeing head keys to route
  anywhere).  Both W-Choices runs use the capacity-relative balanceability
  threshold ``theta = d * c_min / sum(c)`` — the heterogeneous analogue of
  the paper's §5 ``d/n`` limit: a key is only balanceable if its candidate
  set's worst-case capacity share covers its frequency.  A serving-level
  twin drives two W-Choices schedulers through the discrete-event simulator
  on the SAME heterogeneous service rates and bounded queues — one routing
  on normalized loads, one capacity-blind — and gates mean request latency:
  the blind router keeps standing queues on the slow replicas (their fair
  raw-load share exceeds their service rate), the weighted router steers
  around them.  All "imbalance" entries are under the check_regression
  gate, direction up.
* ``elastic_wave`` — a cost wave (2.5x for the middle third of the stream)
  hits a PoTC pool run by serving.sim.Autoscaler.  Gates: the pool scales
  up under the wave and back down after, nothing is lost
  (``completed + shed == m``), and the queue-drain recovery time after the
  wave is a small fraction of the run (``SimResult.sample_outstanding`` is
  the drain curve; tests/test_capacity.py pins the per-transition
  invariants).

`PYTHONPATH=src:. python benchmarks/bench_hetero_elastic.py [--scale S]
[--quick] [--out PATH]` writes the JSON report via the benchmarks/common.py
convention; `run(scale)` yields CSV rows for benchmarks/run.py.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row, bench_main
from repro.core.metrics import capacity_imbalance_fraction
from repro.core.partitioners import pkg_partition, w_choices_partition
from repro.core.streams import zipf_stream
from repro.serving import Autoscaler, WChoicesScheduler, simulate_serving
from repro.serving.scheduler import PoTCScheduler

N_HET = 12
CAPS_124 = np.tile(np.array([1.0, 2.0, 4.0]), N_HET // 3)  # {1x,2x,4x} pool


class _CapacityBlindScheduler(WChoicesScheduler):
    """W-Choices on a heterogeneous cluster that ROUTES capacity-blind.

    The ledger keeps the capacity vector (so serving.sim serves at the true
    heterogeneous rates and samples capacity-normalized imbalance — the
    comparison against the weighted scheduler is apples-to-apples), but
    route() withholds it from the policy: decisions compare raw outstanding
    work, exactly the pre-capacity router."""

    def route(self, key: int, cost: float = 1.0) -> int:
        c = self.policy.decide(
            int(key), self.ledger.loads, self.ledger.live_mask()
        )
        self.ledger.acquire(c, cost)
        return c


def _hetero_scenario(m: int, seed: int) -> dict:
    keys = zipf_stream(m, max(m // 32, 64), 1.4, seed=seed)
    caps = CAPS_124
    # heterogeneous balanceability limit: a key pinned to d candidates is
    # only balanceable if even the slowest candidate pair can cover its
    # frequency, so the head threshold drops from the paper's d/n to
    # d * c_min / sum(c) (both W-Choices runs use it — apples-to-apples)
    theta_het = 2.0 * float(caps.min()) / float(caps.sum())
    entry: dict = {
        "n_workers": N_HET, "n_msgs": m, "capacities": caps.tolist(),
        "theta": theta_het,
        "imbalance": {}, "us_per_msg": {}, "load_share_4x": {},
    }
    parts = {
        "pkg": lambda k, n, capacities: pkg_partition(
            k, n, capacities=capacities),
        "w_choices": lambda k, n, capacities: w_choices_partition(
            k, n, theta=theta_het, capacities=capacities),
    }
    for name, fn in parts.items():
        for tag, cap_arg in ((f"{name}_weighted", caps),
                             (f"{name}_unweighted", None)):
            t0 = time.perf_counter()
            assign = np.asarray(fn(keys, N_HET, capacities=cap_arg))
            dt = time.perf_counter() - t0
            entry["imbalance"][tag] = capacity_imbalance_fraction(assign, caps)
            counts = np.bincount(assign, minlength=N_HET)
            entry["load_share_4x"][tag] = float(
                counts[caps == 4.0].sum() / m
            )
            entry["us_per_msg"][tag] = dt / m * 1e6

    # serving twin: same heterogeneous service rates and bounded queues,
    # weighted vs capacity-blind routing; sample_imbalance is capacity-
    # normalized in both runs because both ledgers carry the capacity vector
    for tag, cls in (("serving_weighted", WChoicesScheduler),
                     ("serving_blind", _CapacityBlindScheduler)):
        sched = cls(N_HET, seed=seed, theta=theta_het, capacities=caps)
        t0 = time.perf_counter()
        res = simulate_serving(sched, keys, utilization=0.9, queue_bound=16)
        dt = time.perf_counter() - t0
        entry["imbalance"][tag] = float(res.sample_imbalance.mean())
        entry["us_per_msg"][tag] = dt / m * 1e6
        entry.setdefault("mean_latency", {})[tag] = float(
            np.nanmean(res.latency))
        entry.setdefault("p99_latency", {})[tag] = res.latency_p99
        entry.setdefault("drop_rate", {})[tag] = res.shed / m
        entry.setdefault("lost", {})[tag] = m - res.completed - res.shed
    return entry


def _elastic_scenario(m: int, seed: int) -> dict:
    n = N_HET
    keys = zipf_stream(m, max(m // 32, 64), 1.2, seed=seed + 1)
    costs = np.ones(m)
    i0, i1 = m // 3, 2 * m // 3
    costs[i0:i1] = 2.5  # the load wave
    asc = Autoscaler(
        min_replicas=4, max_replicas=n, initial=4, high=3.0, low=0.5,
        check_every=max(m // 100, 1), cooldown=max(m // 40, 1),
    )
    sched = PoTCScheduler(n, seed=seed)
    t0 = time.perf_counter()
    res = simulate_serving(
        sched, keys, costs=costs, utilization=0.85, autoscaler=asc,
    )
    dt_wall = time.perf_counter() - t0

    ups = [t for t, d, _ in res.scale_events if d == 1]
    downs = [t for t, d, _ in res.scale_events if d == -1]
    # recovery: after the wave ends, time until total outstanding work first
    # returns to <= 2x its pre-wave mean (the queue-drain transient)
    dt_arr = float(costs.mean()) / (0.85 * asc.initial)
    t_wave_start, t_wave_end = i0 * dt_arr, i1 * dt_arr
    ts, out = res.sample_times, res.sample_outstanding
    pre = out[(ts < t_wave_start)]
    recovery = float("inf")
    if len(pre):
        limit = 2.0 * float(pre.mean())
        ok = np.flatnonzero((ts >= t_wave_end) & (out <= limit))
        if len(ok):
            recovery = float(ts[ok[0]] - t_wave_end)
    return {
        "n_workers": n, "n_msgs": m, "initial_replicas": asc.initial,
        "imbalance": {"potc_elastic": float(np.nanmean(res.sample_imbalance))},
        "us_per_msg": {"potc_elastic": dt_wall / m * 1e6},
        "scale_ups": len(ups), "scale_downs": len(downs),
        "first_scale_up_t": ups[0] if ups else None,
        "wave": [t_wave_start, t_wave_end],
        "recovery_time": recovery,
        "makespan": res.makespan,
        "requeued": res.requeued,
        "lost": {"potc_elastic": m - res.completed - res.shed},
    }


def collect(scale: float = 1.0, seed: int = 0) -> dict:
    """Heterogeneous + elastic sweep; JSON report with acceptance checks."""
    m = max(int(60_000 * scale), 9_000)
    het = _hetero_scenario(m, seed)
    ela = _elastic_scenario(m, seed)
    imb = het["imbalance"]
    checks = {
        # the tentpole payoff: normalizing the argmin by capacity beats the
        # capacity-blind router on the same {1x,2x,4x} pool; PKG is gated
        # no-worse only (its head key is pinned to a fixed d=2 pair — see
        # the module docstring)
        "weighted_pkg_no_worse":
            imb["pkg_weighted"] <= 1.05 * imb["pkg_unweighted"],
        "weighted_w_beats_unweighted":
            imb["w_choices_weighted"] < 0.5 * imb["w_choices_unweighted"],
        # the 4x workers hold more work only when the router knows about them
        "fast_workers_absorb_more":
            het["load_share_4x"]["w_choices_weighted"]
            > het["load_share_4x"]["w_choices_unweighted"],
        # serving twin: requests wait measurably less when the router knows
        # the replica speeds (blind keeps standing queues on slow replicas)
        "serving_weighted_beats_blind":
            het["mean_latency"]["serving_weighted"]
            < 0.95 * het["mean_latency"]["serving_blind"],
        "zero_lost_hetero": all(v == 0 for v in het["lost"].values()),
        # elastic: the wave forces a scale-up, the lull after it a scale-down
        "scaled_up_under_wave": ela["scale_ups"] >= 1,
        "scaled_down_after_wave": ela["scale_downs"] >= 1,
        "zero_lost_elastic": all(v == 0 for v in ela["lost"].values()),
        # the queue drains back to its pre-wave level within 40% of the run
        "rescale_recovery_bounded":
            ela["recovery_time"] <= 0.4 * ela["makespan"],
    }
    return {
        "scenarios": {"hetero_cap124": het, "elastic_wave": ela},
        "checks": checks,
    }


def run(scale: float = 1.0) -> list[Row]:
    rows = []
    report = collect(scale=scale)
    het = report["scenarios"]["hetero_cap124"]
    ela = report["scenarios"]["elastic_wave"]
    for tag, v in het["imbalance"].items():
        rows.append(
            Row(f"hetero_elastic/cap124/{tag}", het["us_per_msg"][tag],
                f"cap_imb={v:.3e}")
        )
    rows.append(
        Row("hetero_elastic/elastic_wave/potc",
            ela["us_per_msg"]["potc_elastic"],
            f"ups={ela['scale_ups']} downs={ela['scale_downs']} "
            f"recovery={ela['recovery_time']:.1f}")
    )
    ok = all(report["checks"].values())
    rows.append(Row("hetero_elastic/checks", 0.0, "pass" if ok else "FAIL"))
    return rows


# CI quick scale, shared with benchmarks/run.py --ci-set.
QUICK_SCALE = 0.2

if __name__ == "__main__":
    bench_main("hetero_elastic", collect, quick_scale=QUICK_SCALE)
