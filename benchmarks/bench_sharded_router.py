"""Multi-device sharded-router sweep: keys/sec, scaling efficiency, and the
imbalance-vs-sync-period tradeoff (DESIGN.md §6.1), on the BENCH_* JSON
convention.

Sweeps shards in {1, 2, 8} x sync_period in {1, 16} x methods {pkg, d, w}
over a skewed zipf stream, plus a heterogeneous-shard tradeoff curve (stream
sorted so the hot keys concentrate on one shard — the regime where load-sync
staleness genuinely costs balance) and a roofline report on the compiled
routed step (flops / HBM bytes vs the memory-bandwidth bound, per-epoch
collective bytes of the psum).

Standalone runs force 8 CPU host devices via XLA_FLAGS before importing jax;
under benchmarks/run.py --ci-set the flag comes from the environment
(ci.yml).  When fewer devices exist, shard counts above the device count run
on the bit-exact single-device emulation (ref_sharded_route) and the entry
is marked "emulated" — assignments and imbalance are identical, wall time is
not a scaling measurement.

Gating (check_regression.py): "imbalance" (up), "imbalance_ratio" vs the
single-core router (up), "keys_per_sec" (down) and "scaling_efficiency"
(down).  The gated keys_per_sec is RELATIVE to the same run's single-core
PKG throughput, so the CPU CI gates the ratios, not the machine-dependent
absolute number; the absolute keys/sec headline ships un-gated under
"abs_keys_per_sec" (the >= 1e8 target is a compiled-TPU number).
"""
from __future__ import annotations

import os

_FLAG = "--xla_force_host_platform_device_count=8"
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " " + _FLAG).strip()

import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.common import Row, bench_main  # noqa: E402
from repro.core import avg_imbalance_fraction, zipf_stream  # noqa: E402
from repro.core.estimation import W_SENTINEL  # noqa: E402
from repro.core.partitioners import _adaptive_n_cand, _head_flags  # noqa: E402
from repro.parallel.sharded_router import (  # noqa: E402
    ref_sharded_route,
    routed_step_roofline,
    sharded_route,
)

QUICK_SCALE = 0.1

W = 32
BLOCK = 128
D_MAX = 8  # D-Choices candidate cap
SHARDS = (1, 2, 8)
SYNCS = (1, 16)
TRADEOFF_SYNCS = (1, 4, 16)
GRID = 8 * 16 * BLOCK  # one N serves every (shards, sync) combination


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def _methods(keys_np: np.ndarray, n_workers: int):
    """method -> (n_cand or None, d_max, w_mode); pre-passes excluded from
    all timings (the routed step is what shards)."""
    nc_d = _adaptive_n_cand(keys_np, n_workers, 2, D_MAX, None, 1024, 2.0, 8)
    flags = _head_flags(keys_np, n_workers, 2, None, 1024, 8)
    nc_w = np.where(flags != 0, np.int32(W_SENTINEL), np.int32(2)).astype(np.int32)
    return {
        "pkg": (None, 2, False),
        "d": (nc_d, D_MAX, False),
        "w": (nc_w, 2, True),
    }


def _route(keys, nc, n_workers, *, d_max, n_shards, sync_period, w_mode,
           emulated: bool):
    fn = ref_sharded_route if emulated else sharded_route
    return fn(
        keys, nc, n_workers, d_max=d_max, n_shards=n_shards,
        sync_period=sync_period, block=BLOCK, w_mode=w_mode,
    )


def bit_exact_check(seed: int) -> bool:
    """sharded(n_shards=1, sync_period=1) vs the single-core Pallas routers
    (interpret mode) — the tentpole's differential, also in
    tests/test_sharded_router.py."""
    from repro.kernels.adaptive_route import adaptive_route, w_route

    n = 2048
    keys = jnp.asarray(zipf_stream(n, 500, 1.6, seed=seed))
    ok = True
    for name, (nc, d_max, w_mode) in _methods(np.asarray(keys), W).items():
        ncj = None if nc is None else jnp.asarray(nc)
        full = jnp.full((n,), 2, jnp.int32) if ncj is None else ncj
        a_s, l_s = ref_sharded_route(
            keys, ncj, W, d_max=d_max, n_shards=1, sync_period=1,
            block=BLOCK, w_mode=w_mode,
        )
        if w_mode:
            flags = (np.asarray(full) == int(W_SENTINEL)).astype(np.int32)
            a_k, l_k = w_route(keys, jnp.asarray(flags), W, d=d_max,
                               chunk=n, block=BLOCK, interpret=True)
        else:
            a_k, l_k = adaptive_route(keys, full, W, d_max=d_max, chunk=n,
                                      block=BLOCK, interpret=True)
        ok = ok and bool(
            (np.asarray(a_s) == np.asarray(a_k)).all()
            and (np.asarray(l_s) == np.asarray(l_k[-1])).all()
        )
    return ok


def collect(scale: float = 1.0, seed: int = 0) -> dict:
    n = max(int(262_144 * scale) // GRID, 1) * GRID
    n_dev = jax.local_device_count()
    keys_np = zipf_stream(n, 1_000, 1.8, seed=seed)
    keys = jnp.asarray(keys_np)
    methods = _methods(keys_np, W)

    # single-core reference: 1 shard, sync_period=1 on the jitted oracle path
    single = {}
    for name, (nc, d_max, w_mode) in methods.items():
        ncj = None if nc is None else jnp.asarray(nc)
        a, _ = ref_sharded_route(keys, ncj, W, d_max=d_max, n_shards=1,
                                 sync_period=1, block=BLOCK, w_mode=w_mode)
        dt = _time(lambda k=keys, c=ncj, dm=d_max, wm=w_mode: ref_sharded_route(
            k, c, W, d_max=dm, n_shards=1, sync_period=1, block=BLOCK, w_mode=wm))
        single[name] = {
            "imbalance": avg_imbalance_fraction(np.asarray(a), W),
            "keys_per_sec": n / dt,
        }
    pkg_single_thru = single["pkg"]["keys_per_sec"]

    scenarios = {}
    conservation_ok = True
    for s in SHARDS:
        emulated = s > n_dev
        for p in SYNCS:
            entry = {
                "n_shards": s, "sync_period": p, "n_workers": W, "n_msgs": n,
                "z": 1.8, "emulated": emulated,
                "imbalance": {}, "imbalance_ratio": {}, "keys_per_sec": {},
                "scaling_efficiency": {}, "abs_keys_per_sec": {},
            }
            for name, (nc, d_max, w_mode) in methods.items():
                ncj = None if nc is None else jnp.asarray(nc)
                a, loads = _route(keys, ncj, W, d_max=d_max, n_shards=s,
                                  sync_period=p, w_mode=w_mode,
                                  emulated=emulated)
                a_np = np.asarray(a)
                hist = np.bincount(a_np, minlength=W).astype(np.float32)
                conservation_ok = conservation_ok and bool(
                    (np.asarray(loads) == hist).all()
                )
                dt = _time(lambda: _route(
                    keys, ncj, W, d_max=d_max, n_shards=s, sync_period=p,
                    w_mode=w_mode, emulated=emulated))
                thru = n / dt
                imb = avg_imbalance_fraction(a_np, W)
                entry["imbalance"][name] = imb
                entry["imbalance_ratio"][name] = imb / max(
                    single[name]["imbalance"], 1e-4
                )
                entry["keys_per_sec"][name] = thru / pkg_single_thru
                entry["scaling_efficiency"][name] = (
                    thru / single[name]["keys_per_sec"] / s
                )
                entry["abs_keys_per_sec"][name] = thru
            scenarios[f"zipf_s{s}_p{p}"] = entry

    # imbalance-vs-sync-period tradeoff on heterogeneous shards: sorted keys
    # concentrate the head on one shard, so stale views genuinely cost
    # balance and the curve is monotone in sync_period.
    keys_sorted = np.sort(keys_np)
    flags_sorted = _head_flags(keys_sorted, W, 2, None, 1024, 8)
    nc_sorted = jnp.asarray(np.where(
        flags_sorted != 0, np.int32(W_SENTINEL), np.int32(2)
    ).astype(np.int32))
    ks = jnp.asarray(keys_sorted)
    hetero_emulated = 8 > n_dev
    tradeoff = {}
    for p in TRADEOFF_SYNCS:
        a, _ = _route(ks, nc_sorted, W, d_max=2, n_shards=8, sync_period=p,
                      w_mode=True, emulated=hetero_emulated)
        h = np.bincount(np.asarray(a), minlength=W)
        tradeoff[p] = float(h.max() - h.mean()) / n
        scenarios[f"hetero_w_p{p}"] = {
            "n_shards": 8, "sync_period": p, "n_workers": W, "n_msgs": n,
            "emulated": hetero_emulated,
            "imbalance": {"w": tradeoff[p]},
        }

    roofline = routed_step_roofline(
        W, n_shards=min(8, n_dev), sync_period=16, n_epochs=4, block=BLOCK,
        d_max=2, w_mode=True,
    )

    return {
        "n_devices": n_dev,
        "single_core": single,
        "scenarios": scenarios,
        "roofline": roofline,
        "checks": {
            "one_shard_sync1_bit_exact": bit_exact_check(seed + 3),
            "load_sync_conservation": conservation_ok,
            "w_tradeoff_monotone_in_sync_period":
                tradeoff[TRADEOFF_SYNCS[0]]
                <= tradeoff[TRADEOFF_SYNCS[-1]] * 1.05,
            "w_beats_pkg_sharded": all(
                e["imbalance"]["w"] < e["imbalance"]["pkg"]
                for name, e in scenarios.items() if name.startswith("zipf_")
            ),
        },
    }


def run(scale: float = 1.0) -> list[Row]:
    report = collect(scale=scale)
    rows = []
    for name, entry in sorted(report["scenarios"].items()):
        for method, thru in sorted(entry.get("abs_keys_per_sec", {}).items()):
            rows.append(Row(
                f"sharded/{name}/{method}",
                1e6 / thru,
                f"{entry['imbalance'][method]:.3e}",
            ))
        if "abs_keys_per_sec" not in entry:
            for method, imb in sorted(entry["imbalance"].items()):
                rows.append(Row(f"sharded/{name}/{method}", 0.0, f"{imb:.3e}"))
    ok = all(report["checks"].values())
    rows.append(Row("sharded/checks", 0.0, "pass" if ok else "FAIL"))
    return rows


if __name__ == "__main__":
    bench_main("sharded_router", collect, quick_scale=QUICK_SCALE)
