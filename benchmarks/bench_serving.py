"""Beyond-paper: the serving-edge prefix-cache/balance tradeoff (DESIGN.md §8).

The paper's cluster story (§7: 175% throughput / 45% latency on Storm) is
about exactly this frontend-routing setting (arXiv 1504.00788 frames it as
"the power of both choices"): requests carry a session/prefix-cache key, and
the router trades cache affinity (sticky KG) against load balance (RR).
This bench drives the discrete-event simulator (serving.sim) over a skewed
multi-tenant session stream at W = 100 replicas — the regime where replicas
outnumber hot sessions and d = 2 stops balancing (arXiv 1510.05714) — and
sweeps the registered routing policies KG / RR / PoTC / W-Choices through
the one substrate (core.routing).

Reported per (scenario, method): prefix-cache hit-rate, routed-work
imbalance (avg imbalance fraction — the gated metric), outstanding-work
imbalance, per-tenant SLO violations, and us/request.  The headline checks
encode the tradeoff ordering: hit-rate KG > W-Choices ~ PoTC > RR while
imbalance W-Choices < PoTC < KG; W-Choices is the only policy on the
Pareto frontier's knee (near-KG hits at near-RR balance).

`PYTHONPATH=src:. python benchmarks/bench_serving.py [--scale S] [--quick]
[--out PATH]` writes the JSON report via the benchmarks/common.py
convention; `run(scale)` yields CSV rows for benchmarks/run.py.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row, bench_main
from repro.core.routing import host_policy_names, make_policy
from repro.core.streams import multi_tenant_stream
from repro.serving import PolicyScheduler, simulate_serving

METHODS = host_policy_names()  # kg, rr, potc, w_choices (+ future host policies)
N_REPLICAS = 100
N_TENANTS = 4
# 0.1 separates policy-induced per-tenant imbalance (KG ~0.43, PoTC ~0.21
# mean I(t)/t at quick scale) from the small-sample noise floor of the
# lightest tenant (~0.08 for W-Choices, ~0.01 for RR at 2.5k msgs / 100
# replicas): the balanced policies pass, the affinity-only ones fail.
SLO = 0.1


def _scenario(keys: np.ndarray, tenants: np.ndarray,
              n_replicas: int, cache_capacity: int, seed: int) -> dict:
    entry: dict = {
        "n_workers": n_replicas, "n_msgs": len(keys),
        "n_tenants": int(tenants.max()) + 1, "slo": SLO,
        "cache_capacity": cache_capacity,
        "imbalance": {}, "hit_rate": {}, "outstanding_imbalance": {},
        "slo_violations": {}, "us_per_msg": {},
    }
    for method in METHODS:
        sched = PolicyScheduler(make_policy(method, n_replicas, d=2, seed=seed))
        t0 = time.perf_counter()
        res = simulate_serving(
            sched, keys, tenants=tenants, utilization=0.7,
            cache_capacity=cache_capacity, slo=SLO,
        )
        dt = time.perf_counter() - t0
        entry["imbalance"][method] = res.assign_imbalance
        entry["hit_rate"][method] = res.hit_rate
        entry["outstanding_imbalance"][method] = res.outstanding_imbalance
        entry["slo_violations"][method] = (
            res.tenant_report["tenants_violating"]
        )
        entry["us_per_msg"][method] = dt / len(keys) * 1e6
    return entry


def collect(scale: float = 1.0, seed: int = 0) -> dict:
    """Multi-tenant serving sweep; JSON report with acceptance checks."""
    m = max(int(100_000 * scale), 8_000)
    scenarios = {}
    # main scenario: heavy skew, uneven tenant shares, W = 100
    keys, tenants = multi_tenant_stream(
        m, n_tenants=N_TENANTS, n_keys=2_000, z=1.6,
        weights=[4, 2, 1, 1], seed=seed,
    )
    scenarios["mt_W100_z1.6"] = _scenario(
        keys, tenants, N_REPLICAS, cache_capacity=64, seed=seed
    )
    # drifting variant: per-tenant head churn — the online tracker inside
    # WChoicesPolicy keeps following the hot set.
    keys_d, tenants_d = multi_tenant_stream(
        m, n_tenants=N_TENANTS, n_keys=2_000, z=1.6,
        weights=[4, 2, 1, 1], half_life=max(m // 8, 1), seed=seed + 1,
    )
    scenarios["mt_W100_drift"] = _scenario(
        keys_d, tenants_d, N_REPLICAS, cache_capacity=64, seed=seed
    )

    main = scenarios["mt_W100_z1.6"]
    hit, imb = main["hit_rate"], main["imbalance"]
    checks = {
        # the tradeoff ordering of the acceptance criteria:
        #   hit-rate  KG > W-Choices ~ PoTC > RR
        #   imbalance W-Choices < PoTC < KG
        "hitrate_kg_highest": hit["kg"] > hit["w_choices"]
        and hit["kg"] > hit["potc"],
        "hitrate_w_close_to_potc":
            0.7 * hit["potc"] <= hit["w_choices"] <= 1.3 * hit["potc"],
        "hitrate_potc_beats_rr": hit["potc"] > hit["rr"],
        "imbalance_ordering_w_potc_kg":
            imb["w_choices"] < imb["potc"] < imb["kg"],
        # the CI assertions of ISSUE satellite 5: W-Choices beats KG on
        # imbalance while beating RR on hit-rate — i.e. it dominates both
        # pure corners on the axis they sacrifice.
        "w_beats_kg_on_imbalance": imb["w_choices"] < imb["kg"],
        "w_beats_rr_on_hitrate": hit["w_choices"] > hit["rr"],
        # balance survives tenant-level head churn
        "w_beats_potc_under_drift":
            scenarios["mt_W100_drift"]["imbalance"]["w_choices"]
            < scenarios["mt_W100_drift"]["imbalance"]["potc"],
        # only the balanced policies keep every tenant inside the SLO
        "w_no_slo_violations": main["slo_violations"]["w_choices"] == 0,
        "kg_violates_slo": main["slo_violations"]["kg"] > 0,
    }
    return {"scenarios": scenarios, "checks": checks}


def run(scale: float = 1.0) -> list[Row]:
    rows = []
    report = collect(scale=scale)
    for name, entry in report["scenarios"].items():
        for method in METHODS:
            rows.append(
                Row(
                    f"serving/{name}/{method}",
                    entry["us_per_msg"][method],
                    f"imb={entry['imbalance'][method]:.3e} "
                    f"hit={entry['hit_rate'][method]:.3f} "
                    f"slo_viol={entry['slo_violations'][method]}",
                )
            )
    ok = all(report["checks"].values())
    rows.append(Row("serving/checks", 0.0, "pass" if ok else "FAIL"))
    return rows


# CI quick scale, shared with benchmarks/run.py --ci-set.
QUICK_SCALE = 0.2

if __name__ == "__main__":
    bench_main("serving", collect, quick_scale=QUICK_SCALE)
