"""Million-key / trace-scale streaming bench: the chunked driver's flat-memory
contract, measured (DESIGN.md chunked-streaming section).

Three scenarios on the BENCH_* JSON convention:

* ``chunked_stream`` — every chunked policy (pkg / d_choices / w_choices)
  routed over a zipf stream fed through core.streams.stream_chunks.  Gated
  ``events_per_sec`` is RELATIVE: chunked throughput over the same driver
  run as one giant chunk on the same events (so CPU CI gates the chunking
  overhead, not the machine); the absolute chunked number ships un-gated as
  ``events_per_sec_abs``.  Gated ``bytes_per_key`` is
  ``ChunkedRouter.state_bytes() / distinct keys`` — the flat-memory number:
  carried routing state is constant, so bytes/key shrinks as keys grow.
* ``rss`` — two subprocess children route the same stream end to end, one
  through the flat pipeline (generator in, per-chunk histogram out), one
  through the materialize-everything pipeline (full key array in, full
  assignment array out), and report their post-warmup RSS growth from
  /proc/self/statm.  Gated ``rss_ratio`` = chunked growth / one-shot growth;
  the ISSUE's hard ``rss_flat`` (ratio <= 0.5) check arms once the child
  stream is >= 3e6 events (below that both growths are allocator noise) —
  the nightly --scale 50 run (1e7 events) exercises it.
* ``trace_ingest`` — tools/make_trace.py fixtures in both real formats
  (Wikipedia pagecounts, key<TAB>ts) read by core.traces and routed by the
  chunked driver; un-gated ingest throughput plus a reader-determinism and
  hash-round-trip check.  No network: the fixtures are synthesized.

Bit-exactness checks (also tests/test_chunked.py): chunked == one-shot for
every policy (pkg vs kernels.pkg_route; d/w vs online_head_tables +
adaptive_route_online), and streaming simulate_serving == array-mode
aggregates.

Scale map: events = 200k * scale, keys = events / 100 — so ``--scale 50`` is
the 1e7-event nightly tier and ``--scale 500`` the un-gated 1e6-key /
1e8-event headline.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time
import warnings
from pathlib import Path

import numpy as np

from benchmarks.common import Row, bench_main
from repro.core.streams import StreamSpec
from repro.core.traces import trace_chunks
from repro.parallel.chunked_driver import ChunkedRouter

QUICK_SCALE = 0.25

BASE_EVENTS = 200_000
W = 32
CHUNK = 8192
BLOCK = 128
Z = 1.4
D_MAX = 8
SS_CAP = 256
DECAY = 4096
RSS_FLAT_MIN_EVENTS = 3_000_000  # below this, RSS growth is allocator noise

_POLICY_KW = {
    "pkg": {},
    "d_choices": dict(d_max=D_MAX, ss_capacity=SS_CAP, decay_period=DECAY),
    "w_choices": dict(ss_capacity=SS_CAP, decay_period=DECAY),
}


def _spec(events: int, n_keys: int) -> StreamSpec:
    return StreamSpec(name="trace_scale", n_msgs=events, n_keys=n_keys, z=Z)


def _route_stream(router: ChunkedRouter, chunks) -> tuple[np.ndarray, int, float]:
    """Route chunks keeping only a histogram (the flat pipeline); returns
    (hist, events, seconds)."""
    hist = np.zeros(router.n_workers, np.int64)

    def on_chunk(a: np.ndarray) -> None:
        hist[:] = hist + np.bincount(a, minlength=router.n_workers)

    t0 = time.perf_counter()
    n = router.route_stream(chunks, on_chunk=on_chunk)
    return hist, n, time.perf_counter() - t0


def _chunked_stream_scenario(events: int, n_keys: int, seed: int) -> dict:
    spec = _spec(events, n_keys)
    # one-shot comparator capped: materializing 1e8 events is what this
    # module exists to avoid — the ratio is measured where both sides fit
    # (rounded to the chunk size: the one-giant-chunk step needs chunk|block)
    cmp_events = max(min(events, 262_144) // CHUNK * CHUNK, CHUNK)
    cmp_keys = np.concatenate(
        list(_spec(cmp_events, n_keys).stream_chunks(CHUNK, seed=seed))
    )
    entry = {
        "n_events": events, "n_keys": n_keys, "n_workers": W,
        "chunk": CHUNK, "block": BLOCK, "z": Z,
        "events_per_sec": {}, "events_per_sec_abs": {},
        "bytes_per_key": {}, "final_imbalance": {},
    }
    for policy, kw in _POLICY_KW.items():
        mk = lambda c: ChunkedRouter(  # noqa: E731
            W, policy, chunk=c, block=BLOCK, seed=seed, **kw
        )
        # warm both step shapes, then time (the sweep is deliberate — hush
        # the driver's retrace warning)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            mk(CHUNK).route_stream(np.zeros(CHUNK, np.int32))
            mk(cmp_events).route_stream(np.zeros(cmp_events, np.int32))
        _, _, dt_one = _route_stream(mk(cmp_events), cmp_keys)
        _, _, dt_chk_cmp = _route_stream(
            mk(CHUNK), _spec(cmp_events, n_keys).stream_chunks(CHUNK, seed=seed)
        )
        hist, n, dt_full = _route_stream(
            mk(CHUNK), spec.stream_chunks(CHUNK, seed=seed)
        )
        assert n == events, (n, events)
        router = mk(CHUNK)
        entry["events_per_sec"][policy] = (cmp_events / dt_chk_cmp) / (
            cmp_events / dt_one
        )
        entry["events_per_sec_abs"][policy] = events / dt_full
        entry["bytes_per_key"][policy] = router.state_bytes() / n_keys
        entry["final_imbalance"][policy] = float(
            hist.max() - hist.mean()
        ) / events
    return entry


# -- RSS experiment (subprocess children; /proc/self/statm resident pages) --

_RSS_CHILD = r"""
import json, os, sys
import numpy as np
from repro.core.streams import StreamSpec
from repro.parallel.chunked_driver import ChunkedRouter

mode, events, n_keys, seed = (
    sys.argv[1], int(sys.argv[2]), int(sys.argv[3]), int(sys.argv[4])
)

def rss():
    with open("/proc/self/statm") as f:
        return int(f.read().split()[1]) * os.sysconf("SC_PAGE_SIZE")

CHUNK, W = 8192, 32
spec = StreamSpec(name="rss", n_msgs=events, n_keys=n_keys, z=1.4)
router = ChunkedRouter(W, "pkg", chunk=CHUNK, seed=seed)
router.route_stream(np.zeros(CHUNK, np.int32))  # compile before baselining
hist = np.zeros(W, np.int64)
def on_chunk(a):
    hist[:] = hist + np.bincount(a, minlength=W)
base = rss()
if mode == "chunked":
    n = router.route_stream(spec.stream_chunks(CHUNK, seed=seed),
                            on_chunk=on_chunk)
else:  # materialize-everything pipeline: keys array in, assignments out
    keys = np.concatenate(list(spec.stream_chunks(CHUNK, seed=seed)))
    a = router.route_stream(keys)
    hist[:] = hist + np.bincount(a, minlength=W)
    n = len(a)
growth = rss() - base
print(json.dumps({"growth_mb": growth / 1e6, "events": int(n),
                  "hist_sum": int(hist.sum())}))
"""


def _rss_child(mode: str, events: int, n_keys: int, seed: int) -> dict:
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    root = Path(__file__).resolve().parent.parent
    env["PYTHONPATH"] = f"{root / 'src'}:{env.get('PYTHONPATH', '')}"
    out = subprocess.run(
        [sys.executable, "-c", _RSS_CHILD, mode, str(events), str(n_keys),
         str(seed)],
        capture_output=True, text=True, env=env, check=True,
    )
    return json.loads(out.stdout.strip().splitlines()[-1])


def _rss_scenario(events: int, n_keys: int, seed: int) -> tuple[dict, bool]:
    # cap the child stream so the materializing child stays runnable; the
    # nightly tier (>= 3e6 after the cap) arms the hard rss_flat check
    rss_events = max(min(events, 10_000_000), 1_000_000)
    rss_keys = max(min(n_keys, rss_events // 100), 1000)
    chk = _rss_child("chunked", rss_events, rss_keys, seed)
    one = _rss_child("oneshot", rss_events, rss_keys, seed)
    assert chk["events"] == one["events"] == rss_events
    assert chk["hist_sum"] == one["hist_sum"] == rss_events
    # 1 MB floor: both numbers ride on allocator noise at that granularity
    ratio = max(chk["growth_mb"], 1.0) / max(one["growth_mb"], 1.0)
    entry = {
        "n_events": rss_events, "n_keys": rss_keys,
        "growth_mb": {"chunked": chk["growth_mb"], "oneshot": one["growth_mb"]},
        "rss_ratio": {"pkg": ratio},
    }
    flat_ok = ratio <= 0.5 if rss_events >= RSS_FLAT_MIN_EVENTS else True
    return entry, flat_ok


def _trace_ingest_scenario(events: int, seed: int, tmp: Path) -> tuple[dict, bool]:
    from tools.make_trace import write_trace_fixture

    fx_events = min(events, 200_000)
    fx_keys = max(fx_events // 100, 1000)
    entry = {"n_events": fx_events, "n_keys": fx_keys,
             "ingest_events_per_sec": {}}
    deterministic = True
    for fmt in ("wikipedia", "kv"):
        path = write_trace_fixture(
            tmp / f"trace.{fmt}", fmt, fx_events, n_keys=fx_keys, z=Z,
            seed=seed,
        )
        router = ChunkedRouter(W, "pkg", chunk=CHUNK, seed=seed)
        router.route_stream(np.zeros(CHUNK, np.int32))  # compile
        hist, n, dt = _route_stream(
            router, trace_chunks(path, fmt, chunk=CHUNK)
        )
        assert n == fx_events, (fmt, n, fx_events)
        r1 = np.concatenate(list(trace_chunks(path, fmt, chunk=CHUNK)))
        r2 = np.concatenate(list(trace_chunks(path, fmt, chunk=CHUNK - BLOCK)))
        deterministic = deterministic and bool(np.array_equal(r1, r2))
        entry["ingest_events_per_sec"][fmt] = fx_events / dt
    return entry, deterministic


# -- bit-exactness checks ---------------------------------------------------


def _chunked_eq_oneshot(seed: int) -> dict:
    """chunked(chunk=c) == one-shot for every policy, c in {512, n} — the
    full sweep (down to c=1) lives in tests/test_chunked.py."""
    import jax.numpy as jnp

    from repro.core.estimation import online_head_tables
    from repro.kernels.adaptive_route import adaptive_route_online
    from repro.kernels.pkg_route import pkg_route

    n = 4096
    keys = np.concatenate(list(_spec(n, 500).stream_chunks(1024, seed=seed)))
    kj = jnp.asarray(keys)
    out = {}
    ref_pkg = np.asarray(
        pkg_route(kj, W, d=2, seed=seed, chunk=n, block=BLOCK)[0]
    )
    refs = {"pkg": ref_pkg}
    for policy in ("d_choices", "w_choices"):
        w_mode = policy == "w_choices"
        d_max = D_MAX if policy == "d_choices" else 2
        tk, tn = online_head_tables(
            kj, BLOCK, SS_CAP, W, d=2, d_max=D_MAX,
            decay_period=DECAY, any_worker=w_mode,
        )
        refs[policy] = np.asarray(adaptive_route_online(
            kj, tk, tn, W, d_base=2, d_max=d_max, seed=seed, chunk=n,
            block=BLOCK, w_mode=w_mode,
        )[0])
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # deliberate chunk-size sweep
        for policy, kw in _POLICY_KW.items():
            ok = True
            for c in (512, n):
                r = ChunkedRouter(
                    W, policy, chunk=c, block=BLOCK, seed=seed, **kw
                )
                ok = ok and bool(
                    np.array_equal(r.route_stream(keys), refs[policy])
                )
            out[f"chunked_eq_oneshot_{policy}"] = ok
    return out


def _sim_stream_eq_array(seed: int) -> bool:
    from repro.serving.scheduler import PoTCScheduler
    from repro.serving.sim import simulate_serving

    keys = np.concatenate(
        list(_spec(20_000, 500).stream_chunks(1024, seed=seed))
    )
    a = simulate_serving(PoTCScheduler(16, seed=seed), keys, sample_every=512)
    s = simulate_serving(
        PoTCScheduler(16, seed=seed),
        _spec(20_000, 500).stream_chunks(1777, seed=seed),
        sample_every=512,
    )
    la = np.sort(a.latency[~np.isnan(a.latency)])
    return bool(
        a.completed == s.completed and a.shed == s.shed
        and a.hit_rate == s.hit_rate and a.makespan == s.makespan
        and np.array_equal(a.assign_hist, s.assign_hist)
        and np.array_equal(la, s.latency)
    )


def collect(scale: float = 1.0, seed: int = 0) -> dict:
    events = max(int(BASE_EVENTS * scale), 10_000)
    n_keys = max(events // 100, 1000)

    scenarios = {
        "chunked_stream": _chunked_stream_scenario(events, n_keys, seed),
    }
    rss_entry, flat_ok = _rss_scenario(events, n_keys, seed)
    scenarios["rss"] = rss_entry
    with tempfile.TemporaryDirectory() as td:
        ingest_entry, det_ok = _trace_ingest_scenario(events, seed, Path(td))
    scenarios["trace_ingest"] = ingest_entry

    checks = _chunked_eq_oneshot(seed + 1)
    checks["trace_reader_deterministic"] = det_ok
    checks["sim_stream_eq_array"] = _sim_stream_eq_array(seed)
    checks["rss_flat"] = flat_ok

    return {
        "n_events": events,
        "n_keys": n_keys,
        "scenarios": scenarios,
        "checks": checks,
    }


def run(scale: float = 1.0) -> list[Row]:
    report = collect(scale=scale)
    rows = []
    cs = report["scenarios"]["chunked_stream"]
    for policy in sorted(cs["events_per_sec_abs"]):
        rows.append(Row(
            f"trace_scale/chunked/{policy}",
            1e6 / cs["events_per_sec_abs"][policy],
            f"{cs['final_imbalance'][policy]:.3e}",
        ))
    rows.append(Row(
        "trace_scale/rss_ratio", 0.0,
        f"{report['scenarios']['rss']['rss_ratio']['pkg']:.3f}",
    ))
    ok = all(report["checks"].values())
    rows.append(Row("trace_scale/checks", 0.0, "pass" if ok else "FAIL"))
    return rows


if __name__ == "__main__":
    bench_main("trace_scale", collect, quick_scale=QUICK_SCALE)
