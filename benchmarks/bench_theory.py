"""Theorem 5.1 empirics: I(m)·n/m across n for Greedy-1 vs Greedy-2 on the
paper's tight-case distribution (uniform over 5n keys, p1 = 1/(5n) ≤ 1/(5n)).

d=2 keeps I(m)·n/m = O(1); d=1 grows ~ln n/ln ln n — the exponential gap of
the power of two choices, in the m >> n² regime the theorem addresses.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row
from repro.core import pkg_partition, uniform_stream

NS = [8, 16, 32, 64]


def run(scale: float = 1.0) -> list[Row]:
    rows = []
    for n in NS:
        m = max(int(40 * n * n * scale), 20_000)
        keys = uniform_stream(m, 5 * n, seed=n)
        ks = jnp.asarray(keys)
        for d in (1, 2):
            t0 = time.perf_counter()
            a = np.asarray(pkg_partition(ks, n, d=d, seed=n))
            dt = time.perf_counter() - t0
            loads = np.bincount(a, minlength=n)
            norm = (loads.max() - loads.mean()) * n / m  # I(m)·n/m
            rows.append(Row(f"theory/n{n}/d{d}", dt / m * 1e6, f"In_over_m={norm:.3f}"))
    return rows
