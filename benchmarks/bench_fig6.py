"""Paper Fig 6: % disagreement between local estimation and the global
oracle (ZF, K=10k, W=5) while both keep good balance."""
from __future__ import annotations

import time

from benchmarks.common import Row
from repro.core import avg_imbalance_fraction, disagreement, simulate_sources
from repro.core.streams import zipf_stream

ZS = [0.4, 0.8, 1.0, 1.2]
SOURCES = [2, 5, 10]


def run(scale: float = 1.0) -> list[Row]:
    rows = []
    m = int(200_000 * scale)
    for z in ZS:
        keys = zipf_stream(m, 10_000, z, seed=4)
        g = simulate_sources(keys, 5, 1, mode="global")
        for s in SOURCES:
            t0 = time.perf_counter()
            l = simulate_sources(keys, 5, s, mode="local")
            dt = time.perf_counter() - t0
            dis = disagreement(g, l) * 100
            frac = avg_imbalance_fraction(l, 5)
            rows.append(
                Row(f"fig6/z{z}/S{s}", dt / m * 1e6, f"disagree%={dis:.1f}|imb={frac:.2e}")
            )
    return rows
