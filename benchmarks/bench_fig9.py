"""Paper Fig 9: number of choices d vs imbalance under extreme skew
(ZF z=1.2): d=2 fails, growing d restores balance at memory cost d*K."""
from __future__ import annotations

from benchmarks.common import Row, imbalance_row
from repro.core.streams import zipf_stream

DS = [2, 3, 4, 6, 9, 15]
WORKERS = [5, 40, 100]


def run(scale: float = 1.0) -> list[Row]:
    rows = []
    m = int(300_000 * scale)
    keys = zipf_stream(m, 100_000, 1.2, seed=7)
    for w in WORKERS:
        for d in DS:
            rows.append(imbalance_row(f"fig9/W{w}/d{d}", "pkg", keys, w, d=d))
    return rows
