"""Beyond-paper: PKG-PoTC MoE routing vs vanilla top-k + aux loss.

Metrics per (experts, k, router-skew): max/mean expert load and the token
drop rate at capacity factor 1.25 — the quantities that set MoE step time
(the hottest expert is the straggler) and quality (drops).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Row
from repro.kernels.moe_pkg_dispatch import moe_pkg_dispatch

CASES = [
    ("mixtral", 8, 2, 1.0),
    ("mixtral-hot", 8, 2, 3.0),
    ("olmoe", 64, 8, 1.0),
    ("olmoe-hot", 64, 8, 3.0),
]


def run(scale: float = 1.0) -> list[Row]:
    rows = []
    T = max(int(16_384 * scale) // 512, 1) * 512  # block-divisible
    key = jax.random.PRNGKey(0)
    for tag, E, k, skew in CASES:
        logits = jax.random.normal(key, (T, E))
        logits = logits.at[:, 0].add(skew - 1.0)  # hot expert
        probs = jax.nn.softmax(logits, -1)
        tv, ti = jax.lax.top_k(probs, 2 * k)
        cand = ti.reshape(T, k, 2).astype(jnp.int32)
        cg = tv.reshape(T, k, 2)
        cap = int(1.25 * T * k / E)

        # vanilla top-k
        topi = ti[:, :k]
        loads_tk = jnp.zeros(E).at[topi.reshape(-1)].add(1.0)
        drops_tk = float(jnp.maximum(loads_tk - cap, 0).sum() / (T * k))

        t0 = time.perf_counter()
        idx, _, loads_pkg = moe_pkg_dispatch(cand, cg, E, block=256)
        dt = time.perf_counter() - t0
        drops_pkg = float(jnp.maximum(loads_pkg - cap, 0).sum() / (T * k))

        mean = T * k / E
        rows.append(
            Row(
                f"moe/{tag}/topk", 0.0,
                f"maxload={float(loads_tk.max())/mean:.2f}|drop%={100*drops_tk:.2f}",
            )
        )
        rows.append(
            Row(
                f"moe/{tag}/pkg", dt / T * 1e6,
                f"maxload={float(loads_pkg.max())/mean:.2f}|drop%={100*drops_pkg:.2f}",
            )
        )
    return rows
