"""Beyond-paper: MoE dispatch balance — vanilla top-k vs PKG-PoTC vs the
adaptive D-/W-Choices dispatch, at the kernel-contract level.

Metrics per (experts, k, router-skew) scenario: per-expert load excess
((max-mean)/assignments — the straggler fraction that sets MoE step time) and
the token drop rate at capacity factor 1.25 (the quality cost).  Both feed
CI's regression gate (check_regression.py: "imbalance" and "drop_rate" are
gated upward); us_per_msg is reported but never gated.  Timings run the
jitted oracle paths (the CPU production path, same convention as
bench_kernels.py); one interpret-mode moe_adaptive_dispatch run per collect
is diffed bit-exactly against the oracle as an acceptance check.

bench_moe_train.py drives the same router modes through the full training
loop; this file isolates the dispatch layer on synthetic router
distributions.

`PYTHONPATH=src:. python benchmarks/bench_moe_balance.py [--quick] [--out P]`
writes BENCH_moe_balance.json via benchmarks/common.py; `run(scale)` yields
CSV rows for benchmarks/run.py.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, bench_main
from repro.kernels import ref
from repro.kernels.moe_pkg_dispatch import moe_adaptive_dispatch
from repro.models.moe import expert_head_tables

CASES = [
    ("mixtral", 8, 2, 1.0),
    ("mixtral-hot", 8, 2, 3.0),
    ("olmoe", 64, 8, 1.0),
    ("olmoe-hot", 64, 8, 3.0),
]
BLOCK = 256
D_MAX = 4


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def _cands(key, T: int, E: int, k: int, skew: float, width: int):
    """Router-ranked candidates/gates (T, k, width) with a hot expert 0."""
    logits = jax.random.normal(key, (T, E))
    logits = logits.at[:, 0].add(skew - 1.0)
    probs = jax.nn.softmax(logits, -1)
    tv, ti = jax.lax.top_k(probs, width * k)
    return ti.reshape(T, k, width).astype(jnp.int32), tv.reshape(T, k, width)


def _score(loads, T: int, k: int, E: int):
    loads = np.asarray(loads, float)
    cap = int(1.25 * T * k / E)
    total = T * k
    return (
        float((loads.max() - loads.mean()) / total),
        float(np.maximum(loads - cap, 0).sum() / total),
    )


def _methods(E: int, k: int):
    """method -> (jitted oracle fn producing (idx, gates, loads), width, w)."""
    pkg = jax.jit(functools.partial(ref.ref_moe_pkg_dispatch, n_experts=E,
                                    block=BLOCK))
    d_ad = jax.jit(functools.partial(
        ref.ref_moe_adaptive_dispatch, n_experts=E, d_base=2,
        d_max=min(D_MAX, E // k), block=BLOCK, w_mode=False,
    ))
    w_ad = jax.jit(functools.partial(
        ref.ref_moe_adaptive_dispatch, n_experts=E, d_base=2, d_max=2,
        block=BLOCK, w_mode=True,
    ))
    return {
        "pkg": (pkg, 2, False),
        "d_choices": (d_ad, min(D_MAX, E // k), False),
        "w_choices": (w_ad, 2, True),
    }


def adaptive_kernel_bit_exact(seed: int, T: int = 1024, E: int = 8,
                              k: int = 2) -> bool:
    """Pallas moe_adaptive_dispatch (interpret) vs the shared-core oracle:
    sentinel tables (w_mode) AND capped tables (d mode), idx+gates+loads."""
    key = jax.random.PRNGKey(seed)
    ok = True
    for w_mode, d_max in ((False, 4), (True, 2)):
        cand, cg = _cands(key, T, E, k, skew=3.0, width=d_max)
        tk, tn = expert_head_tables(
            cand[:, 0, 0], E, BLOCK, d_base=2, d_max=d_max, any_worker=w_mode
        )
        out_k = moe_adaptive_dispatch(
            cand, cg, tk, tn, E, d_base=2, d_max=d_max, block=BLOCK,
            w_mode=w_mode,
        )
        out_r = ref.ref_moe_adaptive_dispatch(
            cand, cg, tk, tn, E, d_base=2, d_max=d_max, block=BLOCK,
            w_mode=w_mode,
        )
        ok = ok and all(
            bool((np.asarray(a) == np.asarray(b)).all())
            for a, b in zip(out_k, out_r)
        )
    return ok


def collect(scale: float = 1.0, seed: int = 0) -> dict:
    # floor at 8 blocks: the acceptance checks compare load-greedy policies,
    # whose per-block stale-load floods only self-correct (and the drop
    # accounting only stabilizes) once capacity spans several blocks
    T = max(int(16_384 * scale) // (2 * BLOCK), 8) * 2 * BLOCK
    key = jax.random.PRNGKey(seed)
    scenarios = {}
    for tag, E, k, skew in CASES:
        entry = {
            "n_experts": E, "top_k": k, "skew": skew, "n_tokens": T,
            "imbalance": {}, "us_per_msg": {}, "drop_rate": {},
        }
        # vanilla top-k: the router's preference, load-blind
        cand2, cg2 = _cands(key, T, E, k, skew, width=2)
        topi = cand2[:, :, 0]
        loads_tk = jnp.zeros(E).at[topi.reshape(-1)].add(1.0)
        entry["imbalance"]["topk"], entry["drop_rate"]["topk"] = _score(
            loads_tk, T, k, E
        )
        entry["us_per_msg"]["topk"] = 0.0

        for method, (fn, width, w_mode) in _methods(E, k).items():
            cand, cg = (cand2, cg2) if width == 2 else _cands(
                key, T, E, k, skew, width
            )
            if method == "pkg":
                args = (cand, cg)
            else:
                tk, tn = expert_head_tables(
                    cand[:, 0, 0], E, BLOCK, d_base=2, d_max=width,
                    any_worker=w_mode,
                )
                args = (cand, cg, tk, tn)
            _, _, loads = fn(*args)
            entry["imbalance"][method], entry["drop_rate"][method] = _score(
                loads, T, k, E
            )
            entry["us_per_msg"][method] = _time(fn, *args) / T * 1e6
        scenarios[tag] = entry

    hot = [s for s in scenarios.values() if s["skew"] > 1.0]
    report = {
        "scenarios": scenarios,
        "checks": {
            # the adaptive modes beat plain PKG dispatch where it hurts most
            "w_beats_pkg_imbalance_hot": all(
                e["imbalance"]["w_choices"] <= e["imbalance"]["pkg"]
                for e in hot
            ),
            "d_no_worse_pkg_drops": all(
                e["drop_rate"]["d_choices"] <= e["drop_rate"]["pkg"] + 1e-9
                for e in scenarios.values()
            ),
            "pkg_family_beats_topk_drops": all(
                e["drop_rate"][m] <= e["drop_rate"]["topk"] + 1e-9
                for e in scenarios.values()
                for m in ("pkg", "d_choices", "w_choices")
            ),
            "adaptive_kernel_bit_exact": adaptive_kernel_bit_exact(seed + 7),
        },
    }
    return report


def run(scale: float = 1.0) -> list[Row]:
    report = collect(scale=scale)
    rows = []
    for tag, entry in sorted(report["scenarios"].items()):
        for method in sorted(entry["imbalance"]):
            rows.append(Row(
                f"moe/{tag}/{method}",
                entry["us_per_msg"][method],
                f"imb={entry['imbalance'][method]:.3e}"
                f"|drop={entry['drop_rate'][method]:.3e}",
            ))
    ok = all(report["checks"].values())
    rows.append(Row("moe/checks", 0.0, "pass" if ok else "FAIL"))
    return rows


# CI quick scale, shared with benchmarks/run.py --ci-set.
QUICK_SCALE = 0.25

if __name__ == "__main__":
    bench_main("moe_balance", collect, quick_scale=QUICK_SCALE)
