"""Paper Fig 10 + Table 3: throughput / latency / memory of the word-count
topology under the M/D/1 queue model (core.storm_sim), WP-matched stream.

  fig10a: saturation throughput vs CPU delay for KG / SG / PKG
  table3: mean latency at 90% of SG's saturation rate
  fig10b: throughput vs memory for aggregation periods T (PKG vs SG vs KG)
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row
from repro.core import (
    QueueModel,
    aggregation_memory,
    hash_partition,
    pkg_partition,
    shuffle_partition,
)
from repro.core.streams import matched_trace_stream

DELAYS_MS = [0.1, 0.4, 1.0]
AGG_PERIODS = [10, 30, 60]  # "seconds" at 10k msgs/s -> window in messages
W = 8


def run(scale: float = 1.0) -> list[Row]:
    rows = []
    m = int(220_000 * scale)
    keys = matched_trace_stream(m, int(29_000 * scale), 0.0932, seed=8)
    ks = jnp.asarray(keys)
    assigns = {
        "KG": np.asarray(hash_partition(ks, W)),
        "SG": np.asarray(shuffle_partition(ks, W)),
        "PKG": np.asarray(pkg_partition(ks, W)),
    }
    t0 = time.perf_counter()
    us = (time.perf_counter() - t0) / m * 1e6

    for d_ms in DELAYS_MS:
        models = {k: QueueModel(a, W, d_ms / 1e3) for k, a in assigns.items()}
        for name, qm in models.items():
            rows.append(
                Row(
                    f"fig10a/D{d_ms}ms/{name}", us,
                    f"sat_msgs_per_s={qm.saturation_throughput:.0f}",
                )
            )
        # Table 3: latency at 90% of SG saturation
        rate = 0.9 * models["SG"].saturation_throughput
        for name, qm in models.items():
            lat = qm.mean_latency(rate)
            rows.append(
                Row(
                    f"table3/D{d_ms}ms/{name}", us,
                    f"latency_ms={lat*1e3:.2f}" if np.isfinite(lat) else "latency_ms=inf",
                )
            )

    # fig10b: memory (live partial counters per worker) per aggregation period
    for T in AGG_PERIODS:
        window = T * 10_000  # 10k msgs/s emulated input rate
        for name, a in assigns.items():
            if name == "KG":
                mem = aggregation_memory(keys, a, W, window=len(keys))
            else:
                mem = aggregation_memory(keys, a, W, window=window)
            rows.append(Row(f"fig10b/T{T}s/{name}", us, f"counters_per_worker={mem:.0f}"))
    return rows
