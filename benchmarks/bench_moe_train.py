"""MoE training-loop benchmark: the closed loop over router modes.

Trains a tiny (CPU-shaped) MoE transformer for a few steps under each router
mode — topk_aux, pkg_potc, d_choices, w_choices — and reports:

  tokens_per_sec  — steady-state training throughput (compile excluded);
                    machine-dependent, never gated directly.
  rel_throughput  — tokens/sec normalized to the same run's topk_aux row;
                    same-machine ratios ARE gated (downward) by
                    check_regression.py, so an adaptive-router slowdown
                    cannot land silently.
  imbalance       — per-expert load excess (max-mean)/assignments of the
                    model's own route() on a hot-expert stream (router
                    weights biased toward expert 0), i.e. the straggler
                    fraction that sets MoE step time.  Gated upward.
  drop_rate       — fraction of assignments past expert capacity at the
                    config's capacity factor.  Gated upward.

The quality scenario drives models.moe.route itself (softmax -> top-k ->
head-table scan -> shared-core dispatch), not the kernel in isolation —
bench_moe_balance.py covers the dispatch layer; this file covers the training
closed loop the modes exist for (ROADMAP "fuse the adaptive policies into
MoE dispatch and close the loop").

`PYTHONPATH=src:. python benchmarks/bench_moe_train.py [--quick] [--out P]`
writes BENCH_moe_train.json via benchmarks/common.py; `run(scale)` yields CSV
rows for benchmarks/run.py.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, bench_main
from repro.configs import TrainConfig, get_config, make_tiny
from repro.models import init_params
from repro.models.moe import route
from repro.optim import adamw_init
from repro.train import make_train_step

MODES = ("topk_aux", "pkg_potc", "d_choices", "w_choices")
ARCH = "olmoe-1b-7b"  # tiny-fied: 8 experts, top-2, pkg_block 16


def _train_tokens_per_sec(cfg, steps: int, B: int, S: int, seed: int):
    """Steady-state tokens/sec of jitted train steps (first step = compile,
    excluded); returns (tokens_per_sec, first_loss, last_loss)."""
    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key)
    opt = adamw_init(params)
    tcfg = TrainConfig(total_steps=steps + 1, warmup_steps=2)
    step = jax.jit(make_train_step(cfg, tcfg))
    k1, k2 = jax.random.split(key)
    batch = {
        "tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab_size),
    }
    params, opt, m = step(params, opt, batch, jnp.int32(0))  # compile + step 0
    first_loss = float(m["loss"])
    jax.block_until_ready(params)
    t0 = time.perf_counter()
    for i in range(steps):
        params, opt, m = step(params, opt, batch, jnp.int32(i + 1))
    jax.block_until_ready(params)
    dt = time.perf_counter() - t0
    return steps * B * S / dt, first_loss, float(m["loss"])


def _route_quality(cfg, T: int, hot_bias: float, seed: int, n_hot: int = 2):
    """Drive the model's route() with n_hot co-hot experts and score the
    resulting assignment: load excess fraction and capacity drop rate at the
    config's capacity factor.

    The hot experts get a DETERMINISTIC logit shift: every token carries a
    fixed direction u and the hot router columns gain hot_bias * u, so the
    top-n_hot ranks are the same experts for (almost) every token.  With
    n_hot=2 = the candidate-pair width, 2-choice PKG-PoTC saturates (both
    candidates of the first slot are hot — the paper's p1 > d/W wall) while
    D-Choices' wider fan and W-Choices' global spill stay balanced: the
    separation the adaptive modes exist to show."""
    E, k = cfg.n_experts, cfg.top_k
    d = cfg.d_model
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    p = {"router": jax.random.normal(k1, (d, E), jnp.float32) * 0.05}
    u = jnp.ones((d,)) / d ** 0.5
    for e in range(n_hot):
        p["router"] = p["router"].at[:, e].add(hot_bias * u)
    x2d = jax.random.normal(k2, (T, d), jnp.float32) + u[None, :]
    idx, _, _ = route(p, x2d, cfg)
    counts = np.bincount(np.asarray(idx).reshape(-1), minlength=E).astype(float)
    total = T * k
    cap = max(int(cfg.capacity_factor * T * k / E + 0.5), 4)
    imbalance = float((counts.max() - counts.mean()) / total)
    drop_rate = float(np.maximum(counts - cap, 0).sum() / total)
    return imbalance, drop_rate


def collect(scale: float = 1.0, seed: int = 0) -> dict:
    base = make_tiny(get_config(ARCH))
    steps = max(int(8 * scale), 2)
    B, S = 2, 64
    T = max(int(4096 * scale) // base.pkg_block, 4) * base.pkg_block

    train = {"tokens_per_sec": {}, "loss_first": {}, "loss_last": {}}
    quality = {"imbalance": {}, "drop_rate": {}}
    for mode in MODES:
        cfg = dataclasses.replace(base, router=mode)
        tps, l0, l1 = _train_tokens_per_sec(cfg, steps, B, S, seed)
        train["tokens_per_sec"][mode] = tps
        train["loss_first"][mode] = l0
        train["loss_last"][mode] = l1
        imb, drop = _route_quality(cfg, T, hot_bias=2.0, seed=seed + 1)
        quality["imbalance"][mode] = imb
        quality["drop_rate"][mode] = drop

    tk = train["tokens_per_sec"]["topk_aux"]
    train["rel_throughput"] = {m: train["tokens_per_sec"][m] / tk for m in MODES}

    q_imb, q_drop = quality["imbalance"], quality["drop_rate"]
    report = {
        "scenarios": {
            f"train_tiny_{ARCH}": dict(
                train, n_experts=base.n_experts, top_k=base.top_k,
                steps=steps, batch=B, seq=S,
            ),
            f"route_hot_{ARCH}": dict(
                quality, n_experts=base.n_experts, top_k=base.top_k,
                n_tokens=T, hot_bias=2.0,
            ),
        },
        "checks": {
            # every mode actually trains (finite losses both ends)
            "all_modes_train": all(
                np.isfinite(train["loss_first"][m])
                and np.isfinite(train["loss_last"][m])
                for m in MODES
            ),
            # the tentpole claim: past the p1 > d/W wall (two co-hot experts
            # saturate the candidate pair) the adaptive modes beat plain
            # PKG-PoTC on balance AND overflow...
            "d_beats_pkg_imbalance": q_imb["d_choices"] < q_imb["pkg_potc"],
            "w_beats_pkg_imbalance": q_imb["w_choices"] < q_imb["pkg_potc"],
            "pkg_saturates_at_wall": q_drop["pkg_potc"] > 0,
            "d_beats_pkg_drops": q_drop["d_choices"] < q_drop["pkg_potc"],
            "w_beats_pkg_drops": q_drop["w_choices"] < q_drop["pkg_potc"],
            # ...and every load-aware mode beats the aux-loss baseline
            "pkg_family_beats_topk_drops": all(
                q_drop[m] <= q_drop["topk_aux"] + 1e-9
                for m in ("pkg_potc", "d_choices", "w_choices")
            ),
            # tiny-CPU wall-clock is noisy; the hard floor here just catches
            # pathological slowdowns — the regression gate tracks the ratio
            "adaptive_throughput_sane": all(
                train["rel_throughput"][m] >= 0.2
                for m in ("d_choices", "w_choices")
            ),
        },
    }
    return report


def run(scale: float = 1.0) -> list[Row]:
    report = collect(scale=scale)
    rows = []
    for scen, entry in sorted(report["scenarios"].items()):
        if "tokens_per_sec" in entry:
            for m in MODES:
                rows.append(Row(
                    f"moe_train/{scen}/{m}", 0.0,
                    f"tok/s={entry['tokens_per_sec'][m]:.0f}"
                    f"|rel={entry['rel_throughput'][m]:.2f}",
                ))
        else:
            for m in MODES:
                rows.append(Row(
                    f"moe_train/{scen}/{m}", 0.0,
                    f"imb={entry['imbalance'][m]:.3e}"
                    f"|drop={entry['drop_rate'][m]:.3e}",
                ))
    ok = all(report["checks"].values())
    rows.append(Row("moe_train/checks", 0.0, "pass" if ok else "FAIL"))
    return rows


# CI quick scale, shared with benchmarks/run.py --ci-set.
QUICK_SCALE = 0.5

if __name__ == "__main__":
    bench_main("moe_train", collect, quick_scale=QUICK_SCALE)
