"""Kernel microbench + W-router sweep, on the BENCH_* JSON convention.

Wall time is measured on the jitted XLA oracle paths (the CPU production
path; Pallas interpret mode is a correctness tool, not a timing target), with
one interpret-mode run per kernel as a sanity check.

The W-router sweep measures the in-kernel W-Choices path (DESIGN.md SS3.3
"In-kernel W-Choices"): per-block head tables emitted with any_worker=True
route head keys through the global-argmin water-fill, the d_max-capped tables
(any_worker=False) are the pre-PR-4 router, and plain PKG anchors the bottom.
W in {8, 50, 100} x tail d in {2, 4} x {stationary, drift} streams; imbalance
entries feed CI's regression gate (check_regression.py), us_per_msg is
reported but never gated.

`PYTHONPATH=src:. python benchmarks/bench_kernels.py [--scale S] [--quick]
[--out PATH]` writes BENCH_kernels.json via benchmarks/common.py; `run(scale)`
yields CSV rows for benchmarks/run.py.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, bench_main
from repro.core import avg_imbalance_fraction, drift_stream, online_head_tables, zipf_stream
from repro.kernels import adaptive_route_online, ref

W_SWEEP = (8, 50, 100)
D_SWEEP = (2, 4)
CAPACITY = 128
CHUNK, BLOCK = 1024, 128
D_CAP = 4  # d_max of the capped (pre-W) router baseline


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def _sweep_streams(n: int, seed: int) -> dict[str, np.ndarray]:
    return {
        "stationary": zipf_stream(n, 1_000, 1.8, seed=seed),
        "drift": drift_stream(
            n, 1_000, 1.8, half_life=max(n // 4, 256), seed=seed + 1
        ),
    }


def _tables(keys, n_workers: int, d: int, d_max: int, any_worker: bool):
    return online_head_tables(
        keys, block=BLOCK, capacity=CAPACITY, n_workers=n_workers,
        d=d, d_max=d_max, any_worker=any_worker,
    )


def _routers(n_workers: int):
    """method name -> (jitted oracle route fn, table spec or None)."""
    routers = {
        "pkg": (
            jax.jit(functools.partial(
                ref.ref_pkg_route, n_workers=n_workers, d=2,
                chunk=CHUNK, block=BLOCK,
            )),
            None,
        ),
        "d_router": (
            jax.jit(functools.partial(
                ref.ref_adaptive_route_online, n_workers=n_workers,
                d_base=2, d_max=D_CAP, chunk=CHUNK, block=BLOCK,
            )),  # w_mode default False: the pre-W router, no water-fill
            (2, D_CAP, False),
        ),
    }
    for d in D_SWEEP:
        routers[f"w_router_d{d}"] = (
            jax.jit(functools.partial(
                ref.ref_w_route_online, n_workers=n_workers,
                d_base=d, d_max=d, chunk=CHUNK, block=BLOCK,
            )),
            (d, d, True),
        )
    return routers


def w_router_bit_exact(n: int = 2048, seed: int = 3) -> bool:
    """Pallas W-router (interpret) vs oracle: sentinel tables, assign+loads.

    Covers W=100 under drift and W=50 (not a power of two) stationary.
    """
    ok = True
    cases = [
        (100, jnp.asarray(drift_stream(n, 500, 1.8, half_life=n // 2, seed=seed))),
        (50, jnp.asarray(zipf_stream(n, 500, 1.8, seed=seed))),
    ]
    for W, keys in cases:
        tk, tn = _tables(keys, W, d=2, d_max=2, any_worker=True)
        a_k, l_k = adaptive_route_online(
            keys, tk, tn, W, d_base=2, d_max=2, chunk=CHUNK, block=BLOCK,
            w_mode=True,
        )
        a_r, l_r = ref.ref_w_route_online(
            keys, tk, tn, W, d_base=2, d_max=2, chunk=CHUNK, block=BLOCK
        )
        ok = ok and bool(
            (np.asarray(a_k) == np.asarray(a_r)).all()
            and (np.asarray(l_k) == np.asarray(l_r)).all()
        )
    return ok


def collect(scale: float = 1.0, seed: int = 0) -> dict:
    """W-router sweep -> JSON report with imbalance/us_per_msg + checks."""
    n = max(int(32_768 * scale) // CHUNK, 2) * CHUNK
    scenarios = {}
    routers = {W: _routers(W) for W in W_SWEEP}  # one jit cache per (W, d)
    for kind, keys_np in _sweep_streams(n, seed).items():
        keys = jnp.asarray(keys_np)
        for W in W_SWEEP:
            entry = {
                "kind": kind, "n_workers": W, "n_msgs": n, "z": 1.8,
                "imbalance": {}, "us_per_msg": {},
            }
            for method, (fn, spec) in routers[W].items():
                if spec is None:
                    args = (keys,)
                else:
                    d, d_max, any_worker = spec
                    args = (keys, *_tables(keys, W, d, d_max, any_worker))
                assign, _ = fn(*args)
                entry["imbalance"][method] = avg_imbalance_fraction(
                    np.asarray(assign), W
                )
                entry["us_per_msg"][method] = _time(fn, *args) / n * 1e6
            scenarios[f"{kind}_w{W}"] = entry

    s100 = scenarios["stationary_w100"]["imbalance"]
    report = {
        "scenarios": scenarios,
        "checks": {
            # the tentpole claim: in-kernel W-Choices makes the device path
            # the best-balanced one where d_max-capped routing gives out
            "w_router_beats_capped_at_w100":
                s100["w_router_d2"] < s100["d_router"],
            "w_router_beats_pkg_everywhere": all(
                e["imbalance"]["w_router_d2"] < e["imbalance"]["pkg"]
                for e in scenarios.values()
            ),
            "w_router_bit_exact": w_router_bit_exact(seed=seed + 3),
        },
    }
    return report


def run(scale: float = 1.0) -> list[Row]:
    rows = []
    key = jax.random.PRNGKey(0)

    # pkg_route oracle (jitted scan)
    n = max(int(131_072 * scale) // 1024, 2) * 1024  # chunk-divisible
    keys = jnp.asarray(zipf_stream(n, 10_000, 1.1, seed=1))
    f = jax.jit(lambda k: ref.ref_pkg_route(k, 32, chunk=1024, block=128))
    dt = _time(f, keys)
    rows.append(Row("kernel/pkg_route_ref", dt / len(keys) * 1e6, f"keys={len(keys)}"))

    # moe dispatch oracle
    T = max(int(16_384 * scale) // 512, 1) * 512
    E, k = 64, 8
    probs = jax.nn.softmax(jax.random.normal(key, (T, E)), -1)
    tv, ti = jax.lax.top_k(probs, 2 * k)
    cand = ti.reshape(-1, k, 2).astype(jnp.int32)
    cg = tv.reshape(-1, k, 2)
    f = jax.jit(lambda c, g: ref.ref_moe_pkg_dispatch(c, g, E, block=256))
    dt = _time(f, cand, cg)
    rows.append(Row("kernel/moe_dispatch_ref", dt / cand.shape[0] * 1e6, f"T={cand.shape[0]}"))

    # flash attention oracle vs naive full-logits timing
    B, S, H, hd = 1, int(1024 * max(scale, 0.25)), 8, 64
    q = jax.random.normal(key, (B, S, H, hd), jnp.bfloat16)
    kk = jax.random.normal(key, (B, S, 2, hd), jnp.bfloat16)
    vv = jax.random.normal(key, (B, S, 2, hd), jnp.bfloat16)
    f = jax.jit(lambda a, b, c: ref.ref_flash_attention(a, b, c))
    dt = _time(f, q, kk, vv)
    rows.append(Row("kernel/attention_ref", dt / S * 1e6, f"S={S}"))

    # rmsnorm
    x = jax.random.normal(key, (4096, 2048), jnp.bfloat16)
    w = jax.random.normal(key, (2048,)) * 0.1
    f = jax.jit(lambda a, b: ref.ref_rmsnorm(a, b))
    dt = _time(f, x, w)
    rows.append(Row("kernel/rmsnorm_ref", dt / 4096 * 1e6, "rows=4096"))

    # interpret-mode sanity (correctness path exists end-to-end)
    from repro.kernels.rmsnorm import rmsnorm

    dt = _time(lambda a, b: rmsnorm(a, b), x[:256], w, reps=1)
    rows.append(Row("kernel/rmsnorm_pallas_interpret", dt / 256 * 1e6, "rows=256"))

    # W-router sweep (imbalance + oracle wallclock per configuration)
    report = collect(scale=scale)
    for name, entry in sorted(report["scenarios"].items()):
        for method in sorted(entry["imbalance"]):
            rows.append(
                Row(
                    f"kernel/w_sweep/{name}/{method}",
                    entry["us_per_msg"][method],
                    f"{entry['imbalance'][method]:.3e}",
                )
            )
    ok = all(report["checks"].values())
    rows.append(Row("kernel/w_sweep/checks", 0.0, "pass" if ok else "FAIL"))
    return rows


# CI quick scale, shared with benchmarks/run.py --ci-set.
QUICK_SCALE = 0.1

if __name__ == "__main__":
    bench_main("kernels", collect, quick_scale=QUICK_SCALE)
