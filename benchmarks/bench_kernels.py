"""Kernel microbench: wall time of the jitted XLA oracle paths (the CPU
production path; Pallas interpret mode is a correctness tool, not a timing
target) + one interpret-mode run per kernel as a sanity check."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Row
from repro.core.streams import zipf_stream
from repro.kernels import ref


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(scale: float = 1.0) -> list[Row]:
    rows = []
    key = jax.random.PRNGKey(0)

    # pkg_route oracle (jitted scan)
    n = max(int(131_072 * scale) // 1024, 2) * 1024  # chunk-divisible
    keys = jnp.asarray(zipf_stream(n, 10_000, 1.1, seed=1))
    f = jax.jit(lambda k: ref.ref_pkg_route(k, 32, chunk=1024, block=128))
    dt = _time(f, keys)
    rows.append(Row("kernel/pkg_route_ref", dt / len(keys) * 1e6, f"keys={len(keys)}"))

    # moe dispatch oracle
    T = max(int(16_384 * scale) // 512, 1) * 512
    E, k = 64, 8
    probs = jax.nn.softmax(jax.random.normal(key, (T, E)), -1)
    tv, ti = jax.lax.top_k(probs, 2 * k)
    cand = ti.reshape(-1, k, 2).astype(jnp.int32)
    cg = tv.reshape(-1, k, 2)
    f = jax.jit(lambda c, g: ref.ref_moe_pkg_dispatch(c, g, E, block=256))
    dt = _time(f, cand, cg)
    rows.append(Row("kernel/moe_dispatch_ref", dt / cand.shape[0] * 1e6, f"T={cand.shape[0]}"))

    # flash attention oracle vs naive full-logits timing
    B, S, H, hd = 1, int(1024 * max(scale, 0.25)), 8, 64
    q = jax.random.normal(key, (B, S, H, hd), jnp.bfloat16)
    kk = jax.random.normal(key, (B, S, 2, hd), jnp.bfloat16)
    vv = jax.random.normal(key, (B, S, 2, hd), jnp.bfloat16)
    f = jax.jit(lambda a, b, c: ref.ref_flash_attention(a, b, c))
    dt = _time(f, q, kk, vv)
    rows.append(Row("kernel/attention_ref", dt / S * 1e6, f"S={S}"))

    # rmsnorm
    x = jax.random.normal(key, (4096, 2048), jnp.bfloat16)
    w = jax.random.normal(key, (2048,)) * 0.1
    f = jax.jit(lambda a, b: ref.ref_rmsnorm(a, b))
    dt = _time(f, x, w)
    rows.append(Row("kernel/rmsnorm_ref", dt / 4096 * 1e6, "rows=4096"))

    # interpret-mode sanity (correctness path exists end-to-end)
    from repro.kernels.rmsnorm import rmsnorm

    dt = _time(lambda a, b: rmsnorm(a, b), x[:256], w, reps=1)
    rows.append(Row("kernel/rmsnorm_pallas_interpret", dt / 256 * 1e6, "rows=256"))
    return rows
