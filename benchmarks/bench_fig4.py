"""Paper Fig 4: average imbalance of H vs PKG-global (G) vs PKG-local (L_S)
across datasets, workers, and source counts."""
from __future__ import annotations

from benchmarks.common import Row, imbalance_row, sources_row
from repro.core.streams import PAPER_DATASETS

WORKERS = [10, 50]
SOURCES = [5, 10]


def run(scale: float = 1.0) -> list[Row]:
    rows = []
    for tag in ("WP", "CT", "LN1", "LN2"):
        spec = PAPER_DATASETS[tag]
        keys = spec.generate(seed=2, scale=0.01 * scale)
        for w in WORKERS:
            rows.append(imbalance_row(f"fig4/{tag}/W{w}/H", "kg", keys, w))
            rows.append(sources_row(f"fig4/{tag}/W{w}/G", keys, w, 1, "global"))
            for s in SOURCES:
                rows.append(sources_row(f"fig4/{tag}/W{w}/L{s}", keys, w, s, "local"))
    return rows
