"""Paper Table 2: fraction of average imbalance for H / PoTC / On-Greedy /
Off-Greedy / PKG on WP- and TW-matched streams, W in {5,10,50,100}.

Streams are scaled (messages AND keys by the same factor) so the m/K ratio
and p1 match the originals; Theorem 5.1 makes the imbalance *fraction*
scale-free in this regime.
"""
from __future__ import annotations

from benchmarks.common import Row, imbalance_row
from repro.core.streams import matched_trace_stream

# (tag, n_msgs, n_keys, p1) at scale=1.0 — 1% of the original sizes
DATASETS = [
    ("WP", 220_000, 29_000, 0.0932),
    ("TW", 1_200_000, 31_000, 0.0267),
]
METHODS = ["kg", "potc", "on_greedy", "off_greedy", "pkg"]
WORKERS = [5, 10, 50, 100]


def run(scale: float = 1.0) -> list[Row]:
    rows = []
    for tag, m, k, p1 in DATASETS:
        keys = matched_trace_stream(int(m * scale), int(k * scale), p1, seed=1)
        for w in WORKERS:
            for meth in METHODS:
                rows.append(
                    imbalance_row(
                        f"table2/{tag}/W{w}/{meth}", meth, keys, w,
                        n_keys=int(k * scale),
                    )
                )
    return rows
