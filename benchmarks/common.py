"""Shared benchmark helpers: timed partitioner runs + row collection.

Every bench module exposes run(scale: float) -> list[Row]; run.py prints
``name,us_per_call,derived`` CSV (us_per_call = wall time per routed message,
derived = the paper's metric for that table/figure).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import (
    PARTITIONERS,
    avg_imbalance_fraction,
    d_choices_partition,
    hash_partition,
    off_greedy_partition,
    on_greedy_partition,
    pkg_partition,
    pkg_partition_batched,
    potc_static_partition,
    shuffle_partition,
    simulate_sources,
    w_choices_partition,
)


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.4f},{self.derived}"


def route(method: str, keys: np.ndarray, n_workers: int, n_keys: Optional[int] = None,
          d: int = 2, seed: int = 0) -> tuple[np.ndarray, float]:
    """Run a partitioner; returns (assignment, seconds). JIT warm-up excluded."""
    ks = jnp.asarray(keys, jnp.int32)
    n_keys = int(n_keys or (int(keys.max()) + 1))

    def call():
        if method == "kg":
            return hash_partition(ks, n_workers, seed=seed)
        if method == "sg":
            return shuffle_partition(ks, n_workers)
        if method == "pkg":
            return pkg_partition(ks, n_workers, d=d, seed=seed)
        if method == "pkg_batched":
            return pkg_partition_batched(ks, n_workers, d=d, seed=seed)
        if method == "potc":
            return potc_static_partition(ks, n_workers, n_keys, d=d, seed=seed)
        if method == "on_greedy":
            return on_greedy_partition(ks, n_workers, n_keys)
        if method == "off_greedy":
            return off_greedy_partition(ks, n_workers, n_keys)
        if method == "d_choices":
            return d_choices_partition(keys, n_workers, d=d, seed=seed)
        if method == "w_choices":
            return w_choices_partition(keys, n_workers, d=d, seed=seed)
        raise ValueError(method)

    a = np.asarray(call())  # warm-up/compile
    t0 = time.perf_counter()
    a = np.asarray(call())
    dt = time.perf_counter() - t0
    return a, dt


def imbalance_row(tag: str, method: str, keys: np.ndarray, n_workers: int,
                  n_keys: Optional[int] = None, d: int = 2) -> Row:
    a, dt = route(method, keys, n_workers, n_keys=n_keys, d=d)
    frac = avg_imbalance_fraction(a, n_workers)
    return Row(tag, dt / len(keys) * 1e6, f"{frac:.3e}")


def sources_row(tag: str, keys: np.ndarray, n_workers: int, n_sources: int,
                mode: str, probe_period: int = 0,
                source_keys: Optional[np.ndarray] = None) -> Row:
    t0 = time.perf_counter()
    a = simulate_sources(keys, n_workers, n_sources, mode=mode,
                         probe_period=probe_period, source_keys=source_keys)
    dt = time.perf_counter() - t0
    frac = avg_imbalance_fraction(a, n_workers)
    return Row(tag, dt / len(keys) * 1e6, f"{frac:.3e}")
