"""Shared benchmark helpers: timed partitioner runs, row collection, and the
single JSON-report convention.

Every bench module exposes run(scale: float) -> list[Row]; run.py prints
``name,us_per_call,derived`` CSV (us_per_call = wall time per routed message,
derived = the paper's metric for that table/figure).

JSON-emitting benches route ALL file output through write_report/bench_main:
reports land at ``--out PATH`` when given, else ``$BENCH_DIR/BENCH_<name>.json``
(BENCH_DIR defaults to cwd), so local runs and CI artifacts use identical
paths and the regression gate (benchmarks/check_regression.py) can diff them.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
from pathlib import Path
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import (
    PARTITIONERS,
    avg_imbalance_fraction,
    d_choices_partition,
    hash_partition,
    off_greedy_partition,
    on_greedy_partition,
    online_d_choices_partition,
    online_w_choices_partition,
    pkg_partition,
    pkg_partition_batched,
    potc_static_partition,
    shuffle_partition,
    simulate_sources,
    w_choices_kernel_partition,
    w_choices_partition,
)


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.4f},{self.derived}"


def route(method: str, keys: np.ndarray, n_workers: int, n_keys: Optional[int] = None,
          d: int = 2, seed: int = 0, **kw) -> tuple[np.ndarray, float]:
    """Run a partitioner; returns (assignment, seconds). JIT warm-up excluded.

    Extra keyword args (capacity, decay_period, theta, ...) pass through to
    the adaptive partitioners, so every bench measures a configuration via
    this one dispatch (no per-bench re-implementations to drift apart).
    """
    ks = jnp.asarray(keys, jnp.int32)
    n_keys = int(n_keys or (int(keys.max()) + 1))

    def call():
        if method == "kg":
            return hash_partition(ks, n_workers, seed=seed)
        if method == "sg":
            return shuffle_partition(ks, n_workers)
        if method == "pkg":
            return pkg_partition(ks, n_workers, d=d, seed=seed)
        if method == "pkg_batched":
            return pkg_partition_batched(ks, n_workers, d=d, seed=seed)
        if method == "potc":
            return potc_static_partition(ks, n_workers, n_keys, d=d, seed=seed)
        if method == "on_greedy":
            return on_greedy_partition(ks, n_workers, n_keys)
        if method == "off_greedy":
            return off_greedy_partition(ks, n_workers, n_keys)
        if method == "d_choices":
            return d_choices_partition(keys, n_workers, d=d, seed=seed, **kw)
        if method == "w_choices":
            return w_choices_partition(keys, n_workers, d=d, seed=seed, **kw)
        if method == "w_choices_kernel":
            return w_choices_kernel_partition(keys, n_workers, d=d, seed=seed, **kw)
        if method == "d_choices_online":
            return online_d_choices_partition(ks, n_workers, d=d, seed=seed, **kw)
        if method == "w_choices_online":
            return online_w_choices_partition(ks, n_workers, d=d, seed=seed, **kw)
        raise ValueError(method)

    a = np.asarray(call())  # warm-up/compile
    t0 = time.perf_counter()
    a = np.asarray(call())
    dt = time.perf_counter() - t0
    return a, dt


def imbalance_row(tag: str, method: str, keys: np.ndarray, n_workers: int,
                  n_keys: Optional[int] = None, d: int = 2) -> Row:
    a, dt = route(method, keys, n_workers, n_keys=n_keys, d=d)
    frac = avg_imbalance_fraction(a, n_workers)
    return Row(tag, dt / len(keys) * 1e6, f"{frac:.3e}")


def sources_row(tag: str, keys: np.ndarray, n_workers: int, n_sources: int,
                mode: str, probe_period: int = 0,
                source_keys: Optional[np.ndarray] = None) -> Row:
    t0 = time.perf_counter()
    a = simulate_sources(keys, n_workers, n_sources, mode=mode,
                         probe_period=probe_period, source_keys=source_keys)
    dt = time.perf_counter() - t0
    frac = avg_imbalance_fraction(a, n_workers)
    return Row(tag, dt / len(keys) * 1e6, f"{frac:.3e}")


# ---------------------------------------------------------------------------
# JSON report convention (the single output path for local runs and CI).
# ---------------------------------------------------------------------------


def report_path(name: str, out: Optional[str] = None) -> Path:
    """Canonical location of a bench report: --out wins, else
    $BENCH_DIR/BENCH_<name>.json (BENCH_DIR defaults to the cwd)."""
    if out:
        return Path(out)
    return Path(os.environ.get("BENCH_DIR", ".")) / f"BENCH_{name}.json"


def write_report(name: str, report: dict, out: Optional[str] = None) -> Path:
    path = report_path(name, out)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")
    return path


def bench_main(
    name: str,
    collect: Callable[..., dict],
    quick_scale: float = 0.05,
    argv: Optional[list[str]] = None,
) -> dict:
    """Shared __main__ for JSON benches: --scale/--seed/--out/--quick.

    Runs collect(scale=..., seed=...), stamps bench metadata, writes the
    report via write_report (the one sanctioned output path), and prints it
    to stdout.  --quick clamps the scale for CI's bench-quick job.
    """
    ap = argparse.ArgumentParser(description=f"bench_{name}")
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="report path (default BENCH_<name>.json)")
    ap.add_argument("--quick", action="store_true",
                    help=f"reduced-size CI mode (scale <= {quick_scale})")
    args = ap.parse_args(argv)
    scale = min(args.scale, quick_scale) if args.quick else args.scale
    t0 = time.time()
    report = collect(scale=scale, seed=args.seed)
    report.update(bench=name, scale=scale, seed=args.seed,
                  seconds=round(time.time() - t0, 2))
    path = write_report(name, report, args.out)
    print(json.dumps(report, indent=2, sort_keys=True))
    print(f"# wrote {path}", file=sys.stderr)
    return report
