"""Beyond-paper: overload control and replica failure at the serving edge.

The paper's §7 cluster result is a latency story, but a latency story only
holds where queues are bounded and replicas can die: arXiv 1610.05121
("Workload Skewness and Variance") shows queues diverge under skew exactly
when ``utilization -> 1``, and reactive re-partitioning is what recovers a
dead worker's keys.  This bench drives the failure- and overload-aware
simulator (serving.sim) through three scenarios over the registered host
policies (KG / RR / PoTC / W-Choices via core.routing):

* ``overload_u1.2_shed`` — offered load at 120% of capacity with a bounded
  per-replica FIFO (queue-based load leveling).  Gates: p99 latency is
  structurally bounded by the queue bound for every policy, nothing is lost
  (``completed + shed == m``), and the balanced policies shed less than
  sticky KG (whose hot replicas saturate while cold ones idle).  The shed
  fraction is exported as ``drop_rate`` (gated "up" by check_regression).
* ``kill2_u0.7`` — two replicas die mid-stream; their pending work drains
  and redistributes through each policy's live-mask mechanism.  Gates: zero
  lost completions everywhere, post-kill imbalance (live replicas only)
  recovers under W-Choices, and the recovery time — first post-kill
  outstanding-imbalance sample back inside 2x the pre-kill mean — is a
  small fraction of the stream for W-Choices.
* ``kill_revive_rewarm`` — a replica dies and later revives with a cold
  prefix cache; sticky KG's sessions return to it, so its local hit-rate
  dips until re-warmed (the measured cache re-warm cost).

`PYTHONPATH=src:. python benchmarks/bench_failover_serving.py [--scale S]
[--quick] [--out PATH]` writes the JSON report via the benchmarks/common.py
convention; `run(scale)` yields CSV rows for benchmarks/run.py.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row, bench_main
from repro.core.routing import host_policy_names, make_policy
from repro.core.streams import zipf_stream
from repro.serving import PolicyScheduler, simulate_serving

METHODS = host_policy_names()  # kg, rr, potc, w_choices (+ future host policies)
N_REPLICAS = 20
UTIL_OVERLOAD = 1.2
QUEUE_BOUND = 8
KILLED = (3, 7)


def _post_kill_imbalance(assign: np.ndarray, i_kill: int, n: int,
                         dead: tuple) -> float:
    """Imbalance fraction of work routed after the kill, over live replicas
    only (a dead replica's zero load is lost capacity, not headroom)."""
    loads = np.bincount(assign[i_kill:], minlength=n).astype(np.float64)
    live = np.delete(loads, list(dead))
    return float((live.max() - live.mean()) / max(live.sum(), 1.0))


def _recovery_time(res, t_kill: float) -> float:
    """Time after t_kill for the outstanding-imbalance series to re-enter
    2x its pre-kill mean (inf if it never does)."""
    ts, vals = res.sample_times, res.sample_imbalance
    pre = vals[ts < t_kill]
    post = ts >= t_kill
    if not len(pre) or not post.any():
        return float("nan")
    limit = max(2.0 * float(pre.mean()), 0.05)
    ok = np.flatnonzero(post & (vals <= limit))
    return float(ts[ok[0]] - t_kill) if len(ok) else float("inf")


def _overload_scenario(keys: np.ndarray, seed: int) -> dict:
    n, m = N_REPLICAS, len(keys)
    entry: dict = {
        "n_workers": n, "n_msgs": m, "utilization": UTIL_OVERLOAD,
        "queue_bound": QUEUE_BOUND,
        "imbalance": {}, "drop_rate": {}, "p50_latency": {},
        "p99_latency": {}, "lost": {}, "us_per_msg": {},
    }
    for method in METHODS:
        sched = PolicyScheduler(make_policy(method, n, d=2, seed=seed))
        t0 = time.perf_counter()
        res = simulate_serving(
            sched, keys, utilization=UTIL_OVERLOAD, queue_bound=QUEUE_BOUND,
        )
        dt = time.perf_counter() - t0
        admitted = res.assign[~res.shed_mask]
        loads = np.bincount(admitted, minlength=n).astype(np.float64)
        entry["imbalance"][method] = float(
            (loads.max() - loads.mean()) / max(loads.sum(), 1.0)
        )
        entry["drop_rate"][method] = res.shed / m
        entry["p50_latency"][method] = res.latency_p50
        entry["p99_latency"][method] = res.latency_p99
        entry["lost"][method] = m - res.completed - res.shed
        entry["us_per_msg"][method] = dt / m * 1e6
    return entry


def _failover_scenario(keys: np.ndarray, seed: int) -> dict:
    n, m = N_REPLICAS, len(keys)
    util = 0.7
    dt_arr = 1.0 / (util * n)  # unit costs
    t_kill = 0.5 * m * dt_arr
    i_kill = int(np.ceil(t_kill / dt_arr))
    entry: dict = {
        "n_workers": n, "n_msgs": m, "utilization": util,
        "killed": list(KILLED), "t_kill": t_kill,
        "imbalance": {}, "recovery_time": {}, "requeued": {},
        "lost": {}, "dead_assignments_post_kill": {}, "us_per_msg": {},
    }
    for method in METHODS:
        sched = PolicyScheduler(make_policy(method, n, d=2, seed=seed))
        t0 = time.perf_counter()
        res = simulate_serving(
            sched, keys, utilization=util,
            kill_schedule=[(t_kill, r) for r in KILLED],
        )
        dt = time.perf_counter() - t0
        entry["imbalance"][method] = _post_kill_imbalance(
            res.assign, i_kill, n, KILLED
        )
        entry["recovery_time"][method] = _recovery_time(res, t_kill)
        entry["requeued"][method] = res.requeued
        entry["lost"][method] = m - res.completed - res.shed
        entry["dead_assignments_post_kill"][method] = int(
            np.isin(res.assign[i_kill:], KILLED).sum()
        )
        entry["us_per_msg"][method] = dt / m * 1e6
    return entry


def _rewarm_scenario(keys: np.ndarray, seed: int) -> dict:
    """KG only: kill + revive the sticky replica 0; its sessions come back
    to a cold cache, so its local hit-rate dips until re-warmed."""
    n, m = N_REPLICAS, len(keys)
    util = 0.7
    dt_arr = 1.0 / (util * n)
    t_kill, t_revive = 0.4 * m * dt_arr, 0.5 * m * dt_arr
    i_kill = int(np.ceil(t_kill / dt_arr))
    i_revive = int(np.ceil(t_revive / dt_arr))
    sched = PolicyScheduler(make_policy("kg", n, d=2, seed=seed))
    res = simulate_serving(
        sched, keys, utilization=util, cache_capacity=64,
        kill_schedule=[(t_kill, 0)], revive_schedule=[(t_revive, 0)],
    )
    on0_pre = (res.assign[:i_kill] == 0) & ~res.shed_mask[:i_kill]
    post = slice(i_revive, m)
    on0_post = (res.assign[post] == 0) & ~res.shed_mask[post]
    # first window of post-revival traffic on the revived replica: the cold
    # cache shows as misses until the working set re-materializes, so the
    # window is a few cache-fills wide (a larger one dilutes the transient)
    idx_post = np.flatnonzero(on0_post)[: 4 * 64]
    hit_pre = float(res.hit[:i_kill][on0_pre].mean()) if on0_pre.any() else 0.0
    hit_post = (
        float(res.hit[post][idx_post].mean()) if len(idx_post) else 0.0
    )
    return {
        "n_workers": n, "n_msgs": m, "t_kill": t_kill, "t_revive": t_revive,
        "hit_rate_replica0_pre_kill": hit_pre,
        "hit_rate_replica0_post_revive": hit_post,
        "revived_receives_traffic": int(on0_post.sum()),
        "lost": {"kg": m - res.completed - res.shed},
    }


def collect(scale: float = 1.0, seed: int = 0) -> dict:
    """Overload + failover sweep; JSON report with acceptance checks."""
    m = max(int(50_000 * scale), 6_000)
    keys = zipf_stream(m, 1_500, 1.3, seed=seed)
    scenarios = {
        "overload_u1.2_shed": _overload_scenario(keys, seed),
        "kill2_u0.7": _failover_scenario(keys, seed),
        "kill_revive_rewarm": _rewarm_scenario(keys, seed),
    }

    over, kill = scenarios["overload_u1.2_shed"], scenarios["kill2_u0.7"]
    rewarm = scenarios["kill_revive_rewarm"]
    stream_T = m / (0.7 * N_REPLICAS)
    checks = {
        # nothing ever falls on the floor: every request completes or is
        # counted as shed, in every scenario, for every policy
        "zero_lost_completions": all(
            v == 0
            for scen in scenarios.values()
            for v in scen["lost"].values()
        ),
        # bounded queues clamp tail latency structurally: an admitted
        # request waits for at most queue_bound predecessors of unit cost
        "p99_bounded_by_queue": all(
            over["p99_latency"][mth] <= QUEUE_BOUND + 1 + 1e-9
            for mth in METHODS
        ),
        # sticky KG saturates its hot replicas (local shedding) while cold
        # ones idle; the balanced policies shed only the true surplus
        "w_sheds_less_than_kg":
            over["drop_rate"]["w_choices"] < over["drop_rate"]["kg"],
        # a dead replica receives nothing after its kill event
        "dead_replicas_get_no_traffic": all(
            v == 0 for v in kill["dead_assignments_post_kill"].values()
        ),
        # post-failure balance: W-Choices redistributes the dead replicas'
        # keys and recovers near-perfect balance over the survivors
        "post_kill_imbalance_recovers_w":
            kill["imbalance"]["w_choices"] < 0.02,
        "post_kill_w_beats_kg":
            kill["imbalance"]["w_choices"] < kill["imbalance"]["kg"],
        # ... and does so quickly (within 10% of the stream duration)
        "recovery_fast_w":
            kill["recovery_time"]["w_choices"] <= 0.1 * stream_T,
        # revival is cold: the sticky replica's local hit-rate dips until
        # its working set re-materializes (the measured re-warm cost)
        "rewarm_dip_kg":
            rewarm["hit_rate_replica0_post_revive"]
            < rewarm["hit_rate_replica0_pre_kill"],
        "revived_replica_reused": rewarm["revived_receives_traffic"] > 0,
    }
    return {"scenarios": scenarios, "checks": checks}


def run(scale: float = 1.0) -> list[Row]:
    rows = []
    report = collect(scale=scale)
    over, kill = (report["scenarios"][s] for s in
                  ("overload_u1.2_shed", "kill2_u0.7"))
    for method in METHODS:
        rows.append(
            Row(
                f"failover_serving/overload/{method}",
                over["us_per_msg"][method],
                f"drop={over['drop_rate'][method]:.3f} "
                f"p99={over['p99_latency'][method]:.2f}",
            )
        )
        rows.append(
            Row(
                f"failover_serving/kill2/{method}",
                kill["us_per_msg"][method],
                f"post_kill_imb={kill['imbalance'][method]:.3e} "
                f"recovery={kill['recovery_time'][method]:.1f}",
            )
        )
    ok = all(report["checks"].values())
    rows.append(Row("failover_serving/checks", 0.0, "pass" if ok else "FAIL"))
    return rows


# CI quick scale, shared with benchmarks/run.py --ci-set.
QUICK_SCALE = 0.2

if __name__ == "__main__":
    bench_main("failover_serving", collect, quick_scale=QUICK_SCALE)
