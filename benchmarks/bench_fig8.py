"""Paper Fig 8: skewed splitting of keys onto sources (LJ-like edge streams:
sources keyed by src vertex / KG, workers keyed by dst vertex) vs uniform
shuffle onto sources."""
from __future__ import annotations

from benchmarks.common import Row, sources_row
from repro.core.streams import graph_edge_stream

SOURCES = [5, 10, 20]
WORKERS = [5, 10, 20]


def run(scale: float = 1.0) -> list[Row]:
    rows = []
    m = int(300_000 * scale)
    src, dst = graph_edge_stream(m, 50_000, 200_000, seed=6)
    for s in SOURCES:
        for w in WORKERS:
            rows.append(
                sources_row(f"fig8/uniform/S{s}/W{w}", dst, w, s, "local")
            )
            rows.append(
                sources_row(
                    f"fig8/skewed/S{s}/W{w}", dst, w, s, "local", source_keys=src
                )
            )
    return rows
