"""TPU-adaptation cost: vector-batched PKG (stale-by-<V loads) vs the exact
sequential scan, across block sizes — quantifies DESIGN.md §2's claim that
block-staleness costs little imbalance."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row
from repro.core import avg_imbalance_fraction, pkg_partition, pkg_partition_batched
from repro.core.streams import zipf_stream

BLOCKS = [64, 128, 256, 512, 1024]


def run(scale: float = 1.0) -> list[Row]:
    rows = []
    m = int(400_000 * scale)
    keys = zipf_stream(m, 50_000, 1.0, seed=9)
    ks = jnp.asarray(keys)
    w = 16
    a = np.asarray(pkg_partition(ks, w))
    t0 = time.perf_counter()
    a = np.asarray(pkg_partition(ks, w))
    dt = time.perf_counter() - t0
    exact = avg_imbalance_fraction(a, w)
    rows.append(Row("batched/exact", dt / m * 1e6, f"{exact:.3e}"))
    for blk in BLOCKS:
        ab = np.asarray(pkg_partition_batched(ks, w, block=blk))
        t0 = time.perf_counter()
        ab = np.asarray(pkg_partition_batched(ks, w, block=blk))
        dt = time.perf_counter() - t0
        frac = avg_imbalance_fraction(ab, w)
        rows.append(Row(f"batched/V{blk}", dt / m * 1e6, f"{frac:.3e}"))
    return rows
