"""Beyond-paper: the imbalance-vs-scale crossover (arXiv 1510.05714 Fig 4-5).

Plain d=2 PKG balances only while p1 <= d/W.  Sweeping the large-deployment
scenarios (W in {50, 100}, Zipf z in [1.4, 2.0]) shows PKG's imbalance
exploding past that bound while D-Choices (skew-adaptive d) and W-Choices
(head keys go anywhere) hold near-perfect balance.  Also verifies that the
adaptive Pallas kernel matches its JAX oracle bit-exactly in interpret mode.

`PYTHONPATH=src:. python benchmarks/bench_scale_choices.py [--scale S]
[--quick] [--out PATH]` writes the JSON report via the benchmarks/common.py
convention (default ./BENCH_scale_choices.json, or $BENCH_DIR); `run(scale)`
yields the usual CSV rows for benchmarks/run.py.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, bench_main, route
from repro.core import SCALE_SCENARIOS, avg_imbalance_fraction
from repro.core.streams import zipf_stream
from repro.kernels import adaptive_route, ref

METHODS = ("pkg", "d_choices", "w_choices")


def kernel_bit_exact(d_max: int = 8, n_workers: int = 100) -> bool:
    """Adaptive Pallas kernel vs ref.py oracle on a skewed stream."""
    keys = jnp.asarray(zipf_stream(4096, 1000, 1.8, seed=9))
    nc = jnp.asarray(
        np.random.default_rng(9).integers(1, d_max + 1, 4096, dtype=np.int32)
    )
    a_k, l_k = adaptive_route(keys, nc, n_workers, d_max=d_max)
    a_r, l_r = ref.ref_adaptive_route(keys, nc, n_workers, d_max=d_max)
    return bool(
        (np.asarray(a_k) == np.asarray(a_r)).all()
        and (np.asarray(l_k) == np.asarray(l_r)).all()
    )


def collect(scale: float = 1.0, seed: int = 0) -> dict:
    """Full sweep as a JSON-serialisable report with acceptance checks."""
    scenarios = {}
    for name, sc in sorted(SCALE_SCENARIOS.items()):
        keys = sc.generate(seed=seed, scale=scale)
        entry = {"n_workers": sc.n_workers, "z": sc.z, "p1": sc.head_fraction(),
                 "n_msgs": len(keys), "imbalance": {}, "us_per_msg": {}}
        for method in METHODS:
            a, dt = route(method, keys, sc.n_workers)
            entry["imbalance"][method] = avg_imbalance_fraction(a, sc.n_workers)
            entry["us_per_msg"][method] = dt / len(keys) * 1e6
        scenarios[name] = entry

    hard = scenarios["W100_z2.0"]["imbalance"]
    report = {
        "scenarios": scenarios,
        "checks": {
            "d_choices_below_pkg_at_W100_z2.0": hard["d_choices"] < hard["pkg"],
            "w_choices_below_pkg_at_W100_z2.0": hard["w_choices"] < hard["pkg"],
            "adaptive_kernel_bit_exact": kernel_bit_exact(),
        },
    }
    return report


def run(scale: float = 1.0) -> list[Row]:
    rows = []
    report = collect(scale=scale)
    for name, entry in report["scenarios"].items():
        for method in METHODS:
            rows.append(
                Row(
                    f"scale_choices/{name}/{method}",
                    entry["us_per_msg"][method],
                    f"{entry['imbalance'][method]:.3e}",
                )
            )
    ok = all(report["checks"].values())
    rows.append(Row("scale_choices/checks", 0.0, "pass" if ok else "FAIL"))
    return rows


# CI quick scale, shared with benchmarks/run.py --ci-set.
QUICK_SCALE = 0.05

if __name__ == "__main__":
    bench_main("scale_choices", collect, quick_scale=QUICK_SCALE)
