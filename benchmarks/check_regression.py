"""Merge bench reports into one BENCH_*.json and gate on imbalance regressions.

CI's bench-quick job runs the JSON benches in --quick mode, merges them here
into a single BENCH_ci.json artifact (keyed by each report's "bench" field),
and fails the build when any (bench, scenario, method) imbalance worsens by
more than --max-ratio vs the committed baseline
(benchmarks/baselines/BENCH_baseline.json), or when any bench's own
acceptance checks are false.  Baseline entries missing from the candidate
report also fail (a renamed bench must not silently leave the gate).
Timings (us_per_msg) are machine-dependent and never gated.  An absolute
floor (--floor) keeps near-zero imbalances (e.g. W-Choices at ~1e-5) from
tripping the ratio on sampling noise.

Regenerate the baseline after an intentional change:

    PYTHONPATH=src:. python benchmarks/bench_scale_choices.py --quick --out /tmp/s.json
    PYTHONPATH=src:. python benchmarks/bench_drift.py --quick --out /tmp/d.json
    PYTHONPATH=src:. python benchmarks/bench_kernels.py --quick --out /tmp/k.json
    PYTHONPATH=src:. python benchmarks/bench_serving.py --quick --out /tmp/v.json
    python benchmarks/check_regression.py --merge /tmp/s.json /tmp/d.json /tmp/k.json /tmp/v.json \
        --out benchmarks/baselines/BENCH_baseline.json
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def merge_reports(paths: list[str]) -> dict:
    merged: dict = {}
    for p in paths:
        report = json.loads(Path(p).read_text())
        merged[report.get("bench", Path(p).stem)] = report
    return merged


def iter_imbalances(merged: dict):
    """Yield ((bench, scenario, method), value) for every imbalance entry."""
    for bench, report in merged.items():
        for scen, entry in report.get("scenarios", {}).items():
            for method, val in entry.get("imbalance", {}).items():
                yield (bench, scen, method), float(val)


def compare(current: dict, baseline: dict, max_ratio: float, floor: float):
    base = dict(iter_imbalances(baseline))
    regressions = []
    for key, val in iter_imbalances(current):
        if key not in base:
            continue  # new scenario/method: no baseline yet, not a regression
        limit = max(max_ratio * base[key], floor)
        if val > limit:
            regressions.append((key, base[key], val, limit))
    return regressions


def missing_entries(current: dict, baseline: dict) -> list[tuple[str, str, str]]:
    """Baseline (bench, scenario, method) keys absent from the candidate.

    A renamed or dropped bench must not silently leave the gate: every entry
    the baseline covers has to show up in the merged report, or the baseline
    has to be regenerated deliberately (see module docstring)."""
    cur = dict(iter_imbalances(current))
    return [key for key in dict(iter_imbalances(baseline)) if key not in cur]


def failed_checks(merged: dict) -> list[tuple[str, str]]:
    return [
        (bench, name)
        for bench, report in merged.items()
        for name, ok in report.get("checks", {}).items()
        if not ok
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--merge", nargs="+", required=True,
                    help="bench report JSONs to merge")
    ap.add_argument("--out", default=None,
                    help="write the merged report here (e.g. BENCH_ci.json)")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline to gate against; omit to skip")
    ap.add_argument("--max-ratio", type=float, default=2.0,
                    help="fail when imbalance exceeds ratio x baseline")
    ap.add_argument("--floor", type=float, default=2e-3,
                    help="absolute imbalance below which ratios are ignored")
    args = ap.parse_args(argv)

    merged = merge_reports(args.merge)
    if args.out:
        out = Path(args.out)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(merged, indent=2, sort_keys=True) + "\n")
        print(f"merged {len(merged)} report(s) -> {out}")

    rc = 0
    for bench, name in failed_checks(merged):
        print(f"CHECK FAILED: {bench}: {name}")
        rc = 1

    if args.baseline:
        baseline = json.loads(Path(args.baseline).read_text())
        regressions = compare(merged, baseline, args.max_ratio, args.floor)
        for (bench, scen, method), b, v, lim in regressions:
            print(
                f"REGRESSION: {bench}/{scen}/{method}: imbalance {v:.4g} "
                f"> limit {lim:.4g} (baseline {b:.4g} x {args.max_ratio})"
            )
            rc = 1
        missing = missing_entries(merged, baseline)
        for bench, scen, method in missing:
            print(
                f"MISSING: {bench}/{scen}/{method} is in the baseline but "
                "absent from the merged report — a renamed/dropped bench "
                "leaves the gate; regenerate the baseline if intentional"
            )
            rc = 1
        if not regressions and not missing:
            n = len(dict(iter_imbalances(merged)))
            print(f"no regressions across {n} imbalance entries")
    return rc


if __name__ == "__main__":
    sys.exit(main())
