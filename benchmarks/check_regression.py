"""Merge bench reports into one BENCH_*.json and gate on metric regressions.

CI's bench-quick job runs the JSON benches in --quick mode, merges them here
into a single BENCH_ci.json artifact (keyed by each report's "bench" field),
and fails the build when, vs the committed baseline
(benchmarks/baselines/BENCH_baseline.json):

  * any (bench, scenario, method) "imbalance", "imbalance_ratio" or
    "drop_rate" entry worsens (grows) by more than --max-ratio, or
  * any "rel_throughput", "keys_per_sec" or "scaling_efficiency" entry
    worsens (shrinks) below baseline/--max-ratio — all three are same-run
    ratios (e.g. keys_per_sec is sharded throughput over the same run's
    single-core PKG throughput, scaling_efficiency is speedup/n_shards), so
    same-machine comparisons are meaningful where absolute tokens/sec or
    keys/sec would not be, or
  * any bench's own acceptance checks are false.

Baseline entries missing from the candidate report also fail (a renamed
bench must not silently leave the gate).  Candidate entries missing from the
baseline are WARNED and listed: a new bench entry ships un-gated until the
baseline is regenerated, and that must be a visible decision, not a silent
default.  Absolute timings (us_per_msg, tokens_per_sec) are
machine-dependent and never gated.  An absolute floor (--floor) keeps
near-zero values (e.g. W-Choices imbalance at ~1e-5, zero drop rates) from
tripping the ratio on sampling noise.

docs/benchmarks.md is the full reference: the BENCH_* report convention,
the gated-metric table, and the exact baseline-regeneration commands.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def merge_reports(paths: list[str]) -> dict:
    merged: dict = {}
    for p in paths:
        report = json.loads(Path(p).read_text())
        merged[report.get("bench", Path(p).stem)] = report
    return merged


# gated metric -> direction: "up" fails when the value grows past
# ratio*baseline (cost metrics), "down" fails when it shrinks below
# baseline/ratio (benefit metrics).  "imbalance" keeps its bare legacy key
# so the committed baseline's existing entries stay valid verbatim; the
# newer metrics are key-prefixed ("drop_rate/<method>", ...).
GATED_METRICS = {
    "imbalance": ("up", ""),
    "imbalance_ratio": ("up", "imbalance_ratio/"),
    "drop_rate": ("up", "drop_rate/"),
    "rel_throughput": ("down", "rel_throughput/"),
    "keys_per_sec": ("down", "keys_per_sec/"),
    "scaling_efficiency": ("down", "scaling_efficiency/"),
    # chunked streaming engine (bench_trace_scale): relative chunked/one-shot
    # throughput, chunked/one-shot RSS growth, and carried state bytes per
    # distinct key — the flat-memory contract, gated
    "events_per_sec": ("down", "events_per_sec/"),
    "rss_ratio": ("up", "rss_ratio/"),
    "bytes_per_key": ("up", "bytes_per_key/"),
}


def iter_gated(merged: dict):
    """Yield ((bench, scenario, key), value, direction) for every gated
    metric entry; `key` is the method name under the metric's prefix."""
    for bench, report in merged.items():
        for scen, entry in report.get("scenarios", {}).items():
            for metric, (direction, prefix) in GATED_METRICS.items():
                for method, val in entry.get(metric, {}).items():
                    yield (bench, scen, prefix + method), float(val), direction


def compare(current: dict, baseline: dict, max_ratio: float, floor: float):
    base = {key: val for key, val, _ in iter_gated(baseline)}
    regressions = []
    for key, val, direction in iter_gated(current):
        if key not in base:
            continue  # new scenario/method: no baseline yet, not a regression
        if direction == "up":
            limit = max(max_ratio * base[key], floor)
            if val > limit:
                regressions.append((key, base[key], val, limit))
        else:
            limit = base[key] / max_ratio
            if limit > floor and val < limit:
                regressions.append((key, base[key], val, limit))
    return regressions


def missing_entries(current: dict, baseline: dict) -> list[tuple[str, str, str]]:
    """Baseline (bench, scenario, key) entries absent from the candidate.

    A renamed or dropped bench must not silently leave the gate: every entry
    the baseline covers has to show up in the merged report, or the baseline
    has to be regenerated deliberately (see module docstring)."""
    cur = {key for key, _, _ in iter_gated(current)}
    return [key for key, _, _ in iter_gated(baseline) if key not in cur]


def unbaselined_entries(current: dict, baseline: dict) -> list[tuple[str, str, str]]:
    """Candidate (bench, scenario, key) entries the baseline doesn't cover.

    compare() skips these (no baseline value to ratio against), which means
    a newly added bench entry ships UN-GATED: it can regress freely until
    someone regenerates the baseline.  The gate warns and lists them so the
    un-gated window is a visible decision rather than a silent default."""
    base = {key for key, _, _ in iter_gated(baseline)}
    return [key for key, _, _ in iter_gated(current) if key not in base]


def failed_checks(merged: dict) -> list[tuple[str, str]]:
    return [
        (bench, name)
        for bench, report in merged.items()
        for name, ok in report.get("checks", {}).items()
        if not ok
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--merge", nargs="+", required=True,
                    help="bench report JSONs to merge")
    ap.add_argument("--out", default=None,
                    help="write the merged report here (e.g. BENCH_ci.json)")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline to gate against; omit to skip")
    ap.add_argument("--max-ratio", type=float, default=2.0,
                    help="fail when imbalance exceeds ratio x baseline")
    ap.add_argument("--floor", type=float, default=2e-3,
                    help="absolute imbalance below which ratios are ignored")
    args = ap.parse_args(argv)

    merged = merge_reports(args.merge)
    if args.out:
        out = Path(args.out)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(merged, indent=2, sort_keys=True) + "\n")
        print(f"merged {len(merged)} report(s) -> {out}")

    rc = 0
    for bench, name in failed_checks(merged):
        print(f"CHECK FAILED: {bench}: {name}")
        rc = 1

    if args.baseline:
        baseline = json.loads(Path(args.baseline).read_text())
        regressions = compare(merged, baseline, args.max_ratio, args.floor)
        for (bench, scen, method), b, v, lim in regressions:
            worse = ">" if v > lim else "<"
            print(
                f"REGRESSION: {bench}/{scen}/{method}: {v:.4g} "
                f"{worse} limit {lim:.4g} (baseline {b:.4g}, ratio {args.max_ratio})"
            )
            rc = 1
        missing = missing_entries(merged, baseline)
        for bench, scen, method in missing:
            print(
                f"MISSING: {bench}/{scen}/{method} is in the baseline but "
                "absent from the merged report — a renamed/dropped bench "
                "leaves the gate; regenerate the baseline if intentional"
            )
            rc = 1
        unbaselined = unbaselined_entries(merged, baseline)
        for bench, scen, method in unbaselined:
            print(
                f"WARNING: {bench}/{scen}/{method} has no baseline entry — "
                "the new entry ships UN-GATED; regenerate the baseline "
                "(see module docstring) to bring it under the gate"
            )
        if not regressions and not missing:
            n = len({key for key, _, _ in iter_gated(merged)})
            gated = n - len(unbaselined)
            print(f"no regressions across {gated} gated entries"
                  + (f" ({len(unbaselined)} un-gated, see warnings)"
                     if unbaselined else ""))
    return rc


if __name__ == "__main__":
    sys.exit(main())
