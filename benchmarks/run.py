"""Benchmark harness: one module per paper table/figure (+ beyond-paper).

Prints ``name,us_per_call,derived`` CSV.  --scale scales stream sizes
(default 0.25 for CI speed; 1.0 ~ 1% of the paper's stream sizes with
matched m/K ratios and p1; --scale 100 approaches the original sizes).
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (
    bench_batched_fidelity,
    bench_drift,
    bench_failover_serving,
    bench_heavy_hitters,
    bench_fig4,
    bench_fig5,
    bench_fig6,
    bench_fig7,
    bench_fig8,
    bench_fig9,
    bench_kernels,
    bench_moe_balance,
    bench_moe_train,
    bench_scale_choices,
    bench_serving,
    bench_storm_sim,
    bench_table2,
    bench_theory,
)

MODULES = [
    ("table2", bench_table2),
    ("fig4", bench_fig4),
    ("fig5", bench_fig5),
    ("fig6", bench_fig6),
    ("fig7", bench_fig7),
    ("fig8", bench_fig8),
    ("fig9", bench_fig9),
    ("storm_sim", bench_storm_sim),
    ("theory", bench_theory),
    ("heavy_hitters", bench_heavy_hitters),
    ("moe_balance", bench_moe_balance),
    ("moe_train", bench_moe_train),
    ("batched_fidelity", bench_batched_fidelity),
    ("kernels", bench_kernels),
    ("scale_choices", bench_scale_choices),
    ("drift", bench_drift),
    ("serving", bench_serving),
    ("failover_serving", bench_failover_serving),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--only", default=None, help="comma-separated module names")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    for name, mod in MODULES:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            rows = mod.run(scale=args.scale)
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            continue
        for row in rows:
            print(row.csv(), flush=True)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr, flush=True)


if __name__ == "__main__":
    main()
