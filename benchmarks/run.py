"""Benchmark harness: one module per paper table/figure (+ beyond-paper).

Default mode prints ``name,us_per_call,derived`` CSV.  --scale scales stream
sizes (default 0.25 for CI speed; 1.0 ~ 1% of the paper's stream sizes with
matched m/K ratios and p1; --scale 100 approaches the original sizes).

--ci-set instead runs the canonical quick-bench list (CI_SET below — the
JSON benches the regression gate covers) through each module's own
bench_main, writing one BENCH_<name>.json per bench under --out.  This list
is THE definition of what bench-quick runs; ci.yml calls

    python benchmarks/run.py --quick --ci-set --out bench-out/

and then merges/gates bench-out/BENCH_*.json with check_regression.py.
Each bench's --quick scale comes from its own QUICK_SCALE constant, so
adding a bench to CI is: give it collect() + QUICK_SCALE, list it here,
regenerate the baseline.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

from benchmarks import (
    bench_batched_fidelity,
    bench_drift,
    bench_failover_serving,
    bench_heavy_hitters,
    bench_hetero_elastic,
    bench_fig4,
    bench_fig5,
    bench_fig6,
    bench_fig7,
    bench_fig8,
    bench_fig9,
    bench_kernels,
    bench_moe_balance,
    bench_moe_train,
    bench_scale_choices,
    bench_serving,
    bench_sharded_router,
    bench_storm_sim,
    bench_table2,
    bench_theory,
    bench_trace_scale,
)
from benchmarks.common import bench_main

MODULES = [
    ("table2", bench_table2),
    ("fig4", bench_fig4),
    ("fig5", bench_fig5),
    ("fig6", bench_fig6),
    ("fig7", bench_fig7),
    ("fig8", bench_fig8),
    ("fig9", bench_fig9),
    ("storm_sim", bench_storm_sim),
    ("theory", bench_theory),
    ("heavy_hitters", bench_heavy_hitters),
    ("moe_balance", bench_moe_balance),
    ("moe_train", bench_moe_train),
    ("batched_fidelity", bench_batched_fidelity),
    ("kernels", bench_kernels),
    ("scale_choices", bench_scale_choices),
    ("drift", bench_drift),
    ("serving", bench_serving),
    ("failover_serving", bench_failover_serving),
    ("hetero_elastic", bench_hetero_elastic),
    ("sharded_router", bench_sharded_router),
    ("trace_scale", bench_trace_scale),
]

# The canonical CI quick-bench list: every JSON bench check_regression.py
# gates.  Order matters only for log readability.
CI_SET = [
    ("scale_choices", bench_scale_choices),
    ("drift", bench_drift),
    ("kernels", bench_kernels),
    ("serving", bench_serving),
    ("moe_balance", bench_moe_balance),
    ("moe_train", bench_moe_train),
    ("failover_serving", bench_failover_serving),
    ("hetero_elastic", bench_hetero_elastic),
    ("sharded_router", bench_sharded_router),
    ("trace_scale", bench_trace_scale),
]


def run_ci_set(out_dir: str, *, quick: bool, scale: float, seed: int,
               only=None) -> list[Path]:
    """Run every CI_SET bench via its bench_main, one JSON report each."""
    paths = []
    for name, mod in CI_SET:
        if only and name not in only:
            continue
        out = Path(out_dir) / f"BENCH_{name}.json"
        argv = ["--scale", str(scale), "--seed", str(seed),
                "--out", str(out)]
        if quick:
            argv.append("--quick")
        t0 = time.time()
        bench_main(name, mod.collect,
                   quick_scale=getattr(mod, "QUICK_SCALE", 0.05), argv=argv)
        print(f"# {name} done in {time.time()-t0:.1f}s",
              file=sys.stderr, flush=True)
        paths.append(out)
    return paths


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=float, default=None,
                    help="stream-size scale (CSV default 0.25, --ci-set 1.0)")
    ap.add_argument("--only", default=None, help="comma-separated module names")
    ap.add_argument("--quick", action="store_true",
                    help="with --ci-set: clamp each bench to its QUICK_SCALE")
    ap.add_argument("--ci-set", action="store_true",
                    help="run the canonical JSON quick-bench list instead of CSV")
    ap.add_argument("--out", default="bench-out",
                    help="with --ci-set: directory for BENCH_<name>.json reports")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    if args.ci_set:
        run_ci_set(args.out, quick=args.quick,
                   scale=1.0 if args.scale is None else args.scale,
                   seed=args.seed, only=only)
        return

    scale = 0.25 if args.scale is None else args.scale
    print("name,us_per_call,derived")
    for name, mod in MODULES:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            rows = mod.run(scale=scale)
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            continue
        for row in rows:
            print(row.csv(), flush=True)
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr, flush=True)


if __name__ == "__main__":
    main()
