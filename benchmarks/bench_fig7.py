"""Paper Fig 7: PKG imbalance vs Zipf exponent z, for several key-space sizes
and worker counts; shows the balanced->unbalanced transition at p1 ~ d/W."""
from __future__ import annotations

from benchmarks.common import Row, imbalance_row
from repro.core.streams import zipf_stream

ZS = [0.6, 1.0, 1.4, 1.8]
KEYS = [10_000, 100_000]
WORKERS = [5, 50]


def run(scale: float = 1.0) -> list[Row]:
    rows = []
    m = int(300_000 * scale)
    for k in KEYS:
        for z in ZS:
            keys = zipf_stream(m, k, z, seed=5)
            for w in WORKERS:
                rows.append(imbalance_row(f"fig7/K{k}/z{z}/W{w}", "pkg", keys, w))
    return rows
