"""Paper Fig 5: imbalance through time for G / L5 / L5P1 (probing every
"minute" ~ 1% of the stream); derived = avg fraction | max fraction."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row
from repro.core import imbalance_series, simulate_sources
from repro.core.streams import PAPER_DATASETS

TECHS = [("G", "global", 0), ("L5", "local", 0), ("L5P1", "probe", None)]


def run(scale: float = 1.0) -> list[Row]:
    rows = []
    for tag in ("WP", "CT"):
        spec = PAPER_DATASETS[tag]
        keys = spec.generate(seed=3, scale=0.01 * scale)
        probe = max(len(keys) // 100, 1)
        for w in (5, 50):
            for name, mode, pp in TECHS:
                t0 = time.perf_counter()
                a = simulate_sources(
                    keys, w, 5, mode=mode, probe_period=pp if pp is not None else probe
                )
                dt = time.perf_counter() - t0
                ts, series = imbalance_series(a, w)
                frac = series / ts  # I(t)/t through time
                rows.append(
                    Row(
                        f"fig5/{tag}/W{w}/{name}",
                        dt / len(keys) * 1e6,
                        f"avg={np.mean(frac):.3e}|max={np.max(frac):.3e}",
                    )
                )
    return rows
