"""Paper §4.2: heavy hitters via per-worker SPACESAVING + mergeable summaries.

Measures top-20 recall and the summed worst-case estimate-error bound under
KG / SG / PKG: PKG gets SG-level balance while a key's estimate merges ≤2
summaries (vs W for SG), so its error bound tracks the sequential case.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row
from repro.core import hash_partition, pkg_partition, shuffle_partition
from repro.core.applications import distributed_heavy_hitters
from repro.core.streams import zipf_stream

W, CAP, TOP = 8, 256, 20


def run(scale: float = 1.0) -> list[Row]:
    rows = []
    m = int(400_000 * scale)
    keys = zipf_stream(m, 50_000, 1.1, seed=11)
    true = np.bincount(keys, minlength=50_000)
    true_top = set(np.argsort(-true)[:TOP])
    ks = jnp.asarray(keys)
    for name, assign in [
        ("KG", hash_partition(ks, W)),
        ("SG", shuffle_partition(ks, W)),
        ("PKG", pkg_partition(ks, W)),
    ]:
        t0 = time.perf_counter()
        topk, err, loads = distributed_heavy_hitters(keys, np.asarray(assign), W, CAP, TOP)
        dt = time.perf_counter() - t0
        recall = len({k for k, _ in topk} & true_top) / TOP
        imb = (loads.max() - loads.mean()) / m
        rows.append(
            Row(
                f"hh/{name}", dt / m * 1e6,
                f"recall@{TOP}={recall:.2f}|err_bound={err}|imbalance={imb:.2e}",
            )
        )
    return rows
