"""Beyond-paper: imbalance under key drift — online vs offline head estimation.

The offline D-/W-Choices variants learn the head set from a whole-stream
SPACESAVING pre-pass, so a drifting head set dilutes every hot key's *average*
frequency while its *instantaneous* frequency stays far above theta — the
pre-pass goes blind exactly when adaptivity matters.  The fully-online
variants (tracker in the scan carry, decayed/windowed mode) follow the head
set as it rotates.  This bench sweeps `core.streams.DRIFT_SCENARIOS`
(stationary, half-life churn at three rates, abrupt shifts, multi-tenant mix)
at W = 100 and reports imbalance per method, plus the online Pallas router's
bit-exactness against its oracle.

`PYTHONPATH=src:. python benchmarks/bench_drift.py [--scale S] [--quick]
[--out PATH]` writes the JSON report via the benchmarks/common.py convention
(default ./BENCH_drift.json, or $BENCH_DIR); `run(scale)` yields CSV rows
for benchmarks/run.py.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, bench_main, route
from repro.core import (
    DRIFT_SCENARIOS,
    avg_imbalance_fraction,
    drift_stream,
    online_head_tables,
)
from repro.kernels import adaptive_route_online, ref

CAPACITY = 256
METHODS = ("pkg", "d_offline", "d_online", "w_offline", "w_online")
CHURN = ("churn_hl32", "churn_hl8", "churn_hl2")


def _decay_period(n_msgs: int) -> int:
    """Windowing policy for the online tracker: ~16 half-lives per stream
    floor-capped so tiny quick-mode streams still get a few windows."""
    return max(n_msgs // 16, 512)


def _route(method: str, keys: np.ndarray, n_workers: int):
    """Dispatch through common.route; online methods get the decayed window."""
    kw = {
        "pkg": ("pkg", {}),
        "d_offline": ("d_choices", {"capacity": CAPACITY}),
        "w_offline": ("w_choices", {"capacity": CAPACITY}),
        "d_online": ("d_choices_online",
                     {"capacity": CAPACITY, "decay_period": _decay_period(len(keys))}),
        "w_online": ("w_choices_online",
                     {"capacity": CAPACITY, "decay_period": _decay_period(len(keys))}),
    }[method]
    return route(kw[0], keys, n_workers, **kw[1])


def online_kernel_bit_exact(n_workers: int = 100, d_max: int = 8) -> bool:
    """Head-table Pallas router vs ref.py oracle on a drifting stream."""
    keys = jnp.asarray(drift_stream(4096, 1000, 1.8, half_life=1024, seed=7))
    tk, tn = online_head_tables(
        keys, block=128, capacity=64, n_workers=n_workers, d_max=d_max,
        decay_period=1024,
    )
    a_k, l_k = adaptive_route_online(keys, tk, tn, n_workers, d_max=d_max)
    a_r, l_r = ref.ref_adaptive_route_online(keys, tk, tn, n_workers, d_max=d_max)
    return bool(
        (np.asarray(a_k) == np.asarray(a_r)).all()
        and (np.asarray(l_k) == np.asarray(l_r)).all()
    )


def collect(scale: float = 1.0, seed: int = 0) -> dict:
    """Sweep DRIFT_SCENARIOS; JSON-serialisable report with acceptance checks."""
    scenarios = {}
    for name, sc in sorted(DRIFT_SCENARIOS.items()):
        keys = sc.generate(seed=seed, scale=scale)
        entry = {
            "kind": sc.kind, "n_workers": sc.n_workers, "z": sc.z,
            "n_msgs": len(keys), "half_life_frac": sc.half_life_frac,
            "decay_period": _decay_period(len(keys)),
            "imbalance": {}, "us_per_msg": {},
        }
        for method in METHODS:
            a, dt = _route(method, keys, sc.n_workers)
            entry["imbalance"][method] = avg_imbalance_fraction(a, sc.n_workers)
            entry["us_per_msg"][method] = dt / len(keys) * 1e6
        scenarios[name] = entry

    def beats(method_on: str, method_off: str, names) -> bool:
        return all(
            scenarios[n]["imbalance"][method_on]
            < scenarios[n]["imbalance"][method_off]
            for n in names
        )

    stat = scenarios["stationary"]["imbalance"]
    hl2 = scenarios["churn_hl2"]["imbalance"]
    report = {
        "scenarios": scenarios,
        "checks": {
            # the tentpole claim: under drift the online estimator wins.  At
            # churn_hl2 the head set turns over too fast for ANY d(k) schedule,
            # so D-Choices online vs offline is a tie there — require strictly
            # better at the moderate rates and no-worse at the extreme one.
            "d_online_beats_offline_under_churn":
                beats("d_online", "d_offline", ("churn_hl32", "churn_hl8")),
            "d_online_not_worse_at_fast_churn":
                hl2["d_online"] <= 1.05 * hl2["d_offline"] + 1e-5,
            "w_online_beats_offline_under_churn": beats("w_online", "w_offline", CHURN),
            "online_beats_pkg_under_churn": beats("d_online", "pkg", CHURN)
            and beats("w_online", "pkg", CHURN),
            # no regression where the offline pre-pass is optimal
            "d_online_matches_offline_stationary":
                stat["d_online"] <= 2.0 * stat["d_offline"] + 1e-4,
            "w_online_matches_offline_stationary":
                stat["w_online"] <= 2.0 * stat["w_offline"] + 1e-4,
            "online_kernel_bit_exact": online_kernel_bit_exact(),
        },
    }
    return report


def run(scale: float = 1.0) -> list[Row]:
    rows = []
    report = collect(scale=scale)
    for name, entry in report["scenarios"].items():
        for method in METHODS:
            rows.append(
                Row(
                    f"drift/{name}/{method}",
                    entry["us_per_msg"][method],
                    f"{entry['imbalance'][method]:.3e}",
                )
            )
    ok = all(report["checks"].values())
    rows.append(Row("drift/checks", 0.0, "pass" if ok else "FAIL"))
    return rows


# CI quick scale, shared with benchmarks/run.py --ci-set.
QUICK_SCALE = 0.1

if __name__ == "__main__":
    bench_main("drift", collect, quick_scale=QUICK_SCALE)
