from repro.optim.adamw import adamw_init, adamw_update, global_norm, clip_by_global_norm
from repro.optim.schedules import make_schedule
