"""LR schedules: linear warmup into cosine / linear / constant decay."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = ["make_schedule"]


def make_schedule(kind: str, base_lr: float, warmup_steps: int, total_steps: int,
                  final_ratio: float = 0.1):
    warmup_steps = max(warmup_steps, 1)

    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        warm = (s + 1.0) / warmup_steps  # nonzero LR from the first step
        frac = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        if kind == "cosine":
            decay = final_ratio + (1 - final_ratio) * 0.5 * (1 + jnp.cos(np.pi * frac))
        elif kind == "linear":
            decay = 1.0 - (1.0 - final_ratio) * frac
        elif kind == "const":
            decay = jnp.ones_like(frac)
        else:
            raise ValueError(kind)
        return base_lr * jnp.minimum(warm, decay)

    return fn
