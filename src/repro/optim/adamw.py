"""AdamW with decoupled weight decay and fp32 moment state.

State shards identically to the parameters (ZeRO: both are FSDP+TP sharded
via the same PartitionSpecs), so per-device optimizer memory is
params_bytes * (4+4)/2 / n_devices regardless of model size.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["adamw_init", "adamw_update", "global_norm", "clip_by_global_norm"]


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), tree), gn


def adamw_update(
    params,
    grads,
    state,
    lr: jnp.ndarray,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
):
    """One AdamW step; returns (new_params, new_state)."""
    count = state["count"] + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1.0 - b1) * gf
        v2 = b2 * v + (1.0 - b2) * gf * gf
        step = (m2 / c1) / (jnp.sqrt(v2 / c2) + eps)
        pf = p.astype(jnp.float32)
        p2 = pf - lr * (step + weight_decay * pf)
        return p2.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}
