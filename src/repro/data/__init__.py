from repro.data.pipeline import PKGDataPipeline, SyntheticCorpus
