"""PKG-balanced streaming data pipeline (the paper's technique at the data edge).

A synthetic corpus emits documents with a skewed *group key* (domain id,
Zipf-distributed — the realistic "some domains dominate the crawl" shape) and
lognormal lengths.  Documents route to data-parallel hosts by key with a
selectable partitioner:

  kg   hash(key) -> host              (baseline; hot domains create stragglers)
  sg   round-robin                    (balanced, but per-key state fans out W×)
  pkg  PoTC + key splitting, load = *tokens* routed per host, local estimates
       (weighted Greedy-2 — the paper generalized to weighted balls)

Stateful per-key bookkeeping downstream (per-domain mixing stats, curriculum
state) stays 2-way mergeable under pkg — the paper's memory argument.

The pipeline is deterministic from (seed, chunk_index) and checkpointable:
`state()`/`load_state()` round-trip through the CheckpointManager, giving
exact data replay after restart (fault tolerance) on any host count that
divides the original (elastic restart).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from repro.core.streams import zipf_probs

__all__ = ["SyntheticCorpus", "PKGDataPipeline"]


@dataclasses.dataclass
class SyntheticCorpus:
    """Deterministic document generator: (doc_key, tokens) per document."""

    vocab_size: int
    n_keys: int = 4096
    zipf_z: float = 1.1
    mean_len: int = 512
    seed: int = 0

    def chunk(self, index: int, n_docs: int = 256):
        rng = np.random.default_rng((self.seed << 20) ^ index)
        probs = zipf_probs(self.n_keys, self.zipf_z)
        cdf = np.cumsum(probs)
        keys = np.searchsorted(cdf, rng.random(n_docs)).astype(np.int32)
        lens = np.maximum(
            16, rng.lognormal(np.log(self.mean_len), 0.6, n_docs)
        ).astype(np.int64)
        # tokens follow a Zipf unigram distribution (natural-language-like;
        # also gives training something learnable immediately)
        tok_cdf = np.cumsum(zipf_probs(self.vocab_size - 1, 1.05))
        docs = [
            (1 + np.searchsorted(tok_cdf, rng.random(l))).astype(np.int32)
            for l in lens
        ]
        return keys, docs


def _hash32(x: np.ndarray, seed: int) -> np.ndarray:
    x = (x.astype(np.uint64) ^ np.uint64(seed * 0x9E3779B9)) & np.uint64(0xFFFFFFFF)
    x = (x ^ (x >> np.uint64(16))) * np.uint64(0x7FEB352D) & np.uint64(0xFFFFFFFF)
    x = (x ^ (x >> np.uint64(15))) * np.uint64(0x846CA68B) & np.uint64(0xFFFFFFFF)
    return (x ^ (x >> np.uint64(16))).astype(np.uint32)


class PKGDataPipeline:
    """Host-sharded token batches balanced with PKG (weighted Greedy-2)."""

    def __init__(
        self,
        batch_size: int,
        seq_len: int,
        vocab_size: int,
        n_hosts: int = 1,
        host_id: int = 0,
        partitioner: str = "pkg",
        corpus: Optional[SyntheticCorpus] = None,
        seed: int = 0,
    ):
        assert partitioner in ("pkg", "kg", "sg")
        self.batch_size, self.seq_len = batch_size, seq_len
        self.n_hosts, self.host_id = n_hosts, host_id
        self.partitioner = partitioner
        self.corpus = corpus or SyntheticCorpus(vocab_size, seed=seed)
        self.seed = seed
        self._chunk_index = 0
        self._rr = 0  # round-robin cursor (sg)
        self._loads = np.zeros(n_hosts, dtype=np.int64)  # local token loads
        self._buffer = np.zeros((0,), dtype=np.int32)

    # ------------------------------------------------------------ routing
    def _route(self, keys: np.ndarray, lens: np.ndarray) -> np.ndarray:
        n = self.n_hosts
        if n == 1:
            return np.zeros(len(keys), np.int32)
        if self.partitioner == "kg":
            return (_hash32(keys, self.seed) % n).astype(np.int32)
        if self.partitioner == "sg":
            out = (self._rr + np.arange(len(keys))) % n
            self._rr = int((self._rr + len(keys)) % n)
            return out.astype(np.int32)
        # pkg: weighted Greedy-2 with persistent local load estimates
        h1 = _hash32(keys, self.seed) % n
        h2 = _hash32(keys, self.seed + 1) % n
        out = np.empty(len(keys), np.int32)
        for i, (a, b, w) in enumerate(zip(h1, h2, lens)):
            c = a if self._loads[a] <= self._loads[b] else b
            self._loads[c] += w
            out[i] = c
        return out

    # ------------------------------------------------------------- batches
    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        need = self.batch_size * (self.seq_len + 1)
        while len(self._buffer) < need:
            keys, docs = self.corpus.chunk(self._chunk_index)
            self._chunk_index += 1
            lens = np.array([len(d) for d in docs], np.int64)
            hosts = self._route(keys, lens)
            mine = [d for d, h in zip(docs, hosts) if h == self.host_id]
            if mine:
                self._buffer = np.concatenate([self._buffer] + mine)
        flat = self._buffer[:need].reshape(self.batch_size, self.seq_len + 1)
        self._buffer = self._buffer[need:]
        return {"tokens": flat[:, :-1].copy(), "labels": flat[:, 1:].copy()}

    # ------------------------------------------------------ checkpointing
    def state(self) -> dict:
        return {
            "chunk_index": np.int64(self._chunk_index),
            "rr": np.int64(self._rr),
            "loads": self._loads.copy(),
            "buffer": self._buffer.copy(),
        }

    def load_state(self, state: dict) -> None:
        self._chunk_index = int(state["chunk_index"])
        self._rr = int(state["rr"])
        self._loads = np.asarray(state["loads"]).astype(np.int64).copy()
        self._buffer = np.asarray(state["buffer"]).astype(np.int32).copy()

    # -------------------------------------------------------- diagnostics
    def host_loads(self) -> np.ndarray:
        return self._loads.copy()
