"""Decoder stack: scan-over-superblocks transformer covering all 10 archs.

A config's `attn_pattern` (e.g. 5×local+1×global for gemma3, rglru/rglru/local
for recurrentgemma, ssd for mamba2) defines a *superblock*; parameters of the
`n_layers // len(pattern)` superblocks are stacked on a leading axis and the
stack runs as one lax.scan (compact HLO, fast SPMD compiles at 95 layers).
Layers beyond the last full superblock ("remainder") are unrolled.

Public API:
  init_defs / init_params    ParamDef tree -> materialized params
  forward(params, batch)     train/prefill logits (+ MoE aux loss)
  loss_fn                    CE + z-loss (+ aux), label -1 = masked
  init_cache / decode_step   single-token decode over stacked caches
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import ssd as S
from repro.parallel.spec import ParamDef, materialize


def _id_sh(name, x):
    return x


# ------------------------------------------------------------- definitions
def _layer_defs(cfg, kind: str) -> dict:
    d = {"ln1": L.rmsnorm_defs(cfg.d_model)}
    if kind in ("global", "local"):
        d["mix"] = L.attention_defs(cfg)
    elif kind == "rglru":
        d["mix"] = R.rglru_defs(cfg)
    elif kind == "ssd":
        d["mix"] = S.ssd_defs(cfg)
        return d  # mamba2 block has no separate MLP
    else:
        raise ValueError(kind)
    d["ln2"] = L.rmsnorm_defs(cfg.d_model)
    d["mlp"] = M.moe_defs(cfg) if cfg.n_experts else L.mlp_defs(cfg)
    return d


def _stack_defs(defs: dict, n: int) -> dict:
    """Prepend a stacked 'layers' axis to every ParamDef leaf."""
    return jax.tree_util.tree_map(
        lambda p: ParamDef(
            (n,) + p.shape, ("layers",) + p.axes,
            init=p.init, scale=p.scale, fan_in=p.fan_in,
        ),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def init_defs(cfg) -> dict:
    pattern = cfg.attn_pattern
    n_sb, n_rem = cfg.n_superblocks, cfg.n_remainder
    defs = {"embed": L.embed_defs(cfg), "final_norm": L.rmsnorm_defs(cfg.d_model)}
    if n_sb:
        defs["superblocks"] = tuple(
            _stack_defs(_layer_defs(cfg, k), n_sb) for k in pattern
        )
    defs["remainder"] = tuple(
        _layer_defs(cfg, pattern[i % len(pattern)]) for i in range(n_rem)
    )
    return defs


def init_params(cfg, key: jax.Array, param_dtype=jnp.float32):
    return materialize(init_defs(cfg), key, param_dtype)


# ------------------------------------------------------------------ layers
def _apply_layer(p, x, cfg, kind, sh, pos_offset=0):
    """Pre-norm residual layer; returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.rmsnorm(p["ln1"], x)
    if kind in ("global", "local"):
        mix = L.attention_apply(p["mix"], h, cfg, kind, sh=sh, pos_offset=pos_offset)
    elif kind == "rglru":
        mix = R.rglru_block_apply(p["mix"], h, cfg, sh=sh)
    else:  # ssd
        mix = S.ssd_apply(p["mix"], h, cfg, sh=sh)
    x = sh("residual", x + mix)
    if kind == "ssd":
        return x, aux
    h = L.rmsnorm(p["ln2"], x)
    if cfg.n_experts:
        y, aux = M.moe_apply(p["mlp"], h, cfg, sh=sh)
    else:
        y = L.mlp_apply(p["mlp"], h, cfg, sh=sh)
    return sh("residual", x + y), aux


def forward_hidden(
    params,
    batch: dict,
    cfg,
    sh: Callable = _id_sh,
    remat: bool = True,
    compute_dtype=jnp.bfloat16,
):
    """Full-sequence forward up to the final norm: returns (x (B,S,D), aux)."""
    if cfg.frontend == "audio_stub":
        x = batch["embeds"].astype(compute_dtype)
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    else:
        x = L.embed_apply(params["embed"], batch["tokens"], cfg).astype(compute_dtype)
    x = sh("residual", x)
    pattern = cfg.attn_pattern
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.n_superblocks:

        def body(carry, sb_params):
            x, aux = carry
            for j, kind in enumerate(pattern):
                x, a = _apply_layer(sb_params[j], x, cfg, kind, sh)
                aux = aux + a
            return (x, aux), None

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        (x, aux_total), _ = lax.scan(body, (x, aux_total), params["superblocks"])

    for i, p in enumerate(params["remainder"]):
        kind = pattern[i % len(pattern)]
        fn = functools.partial(_apply_layer, cfg=cfg, kind=kind, sh=sh)
        if remat:
            fn = jax.checkpoint(fn, prevent_cse=False)
        x, a = fn(p, x)
        aux_total = aux_total + a

    x = L.rmsnorm(params["final_norm"], x)
    return x, aux_total


def forward(
    params,
    batch: dict,
    cfg,
    sh: Callable = _id_sh,
    remat: bool = True,
    compute_dtype=jnp.bfloat16,
):
    """Full-sequence forward. Returns (logits, aux): (B,S,V) or (B,S,heads,V)."""
    x, aux = forward_hidden(params, batch, cfg, sh=sh, remat=remat, compute_dtype=compute_dtype)
    logits = L.unembed_apply(params["embed"], x, cfg)
    return sh("logits", logits), aux


def prefill_logits(
    params, batch: dict, cfg, sh: Callable = _id_sh, compute_dtype=jnp.bfloat16
):
    """Inference prefill: hidden states for the whole prompt, logits only for
    the last position (what a serving prefill actually returns)."""
    x, _ = forward_hidden(params, batch, cfg, sh=sh, remat=False, compute_dtype=compute_dtype)
    logits = L.unembed_apply(params["embed"], x[:, -1:], cfg)
    return sh("logits", logits)


def loss_fn(
    params, batch: dict, cfg, sh: Callable = _id_sh, remat: bool = True, z_loss: float = 1e-4
):
    """Next-token CE (+ z-loss + MoE aux).  labels == -1 are masked."""
    logits, aux = forward(params, batch, cfg, sh=sh, remat=remat)
    labels = batch["labels"]
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = (lse - gold) * mask
    zl = z_loss * jnp.square(lse) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll.sum() + zl.sum()) / denom + aux
    return loss, {"nll": nll.sum() / denom, "aux": aux, "ntok": denom}


# ------------------------------------------------------------------ decode
def _layer_cache(cfg, kind, batch, max_len, dtype):
    if kind in ("global", "local"):
        T = min(cfg.window, max_len) if (kind == "local" and cfg.window) else max_len
        shp = (batch, T, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}
    if kind == "rglru":
        return R.rglru_init_state(cfg, batch, dtype)
    if kind == "ssd":
        return S.ssd_init_state(cfg, batch, dtype)
    raise ValueError(kind)


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    pattern = cfg.attn_pattern
    cache = {"superblocks": tuple(), "remainder": tuple()}
    if cfg.n_superblocks:
        def stack(kind):
            one = _layer_cache(cfg, kind, batch, max_len, dtype)
            return jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (cfg.n_superblocks,) + a.shape).copy(), one
            )
        cache["superblocks"] = tuple(stack(k) for k in pattern)
    cache["remainder"] = tuple(
        _layer_cache(cfg, pattern[i % len(pattern)], batch, max_len, dtype)
        for i in range(cfg.n_remainder)
    )
    return cache


def _decode_layer(p, x, c, pos, cfg, kind, sh):
    h = L.rmsnorm(p["ln1"], x)
    if kind in ("global", "local"):
        mix, c = L.attention_decode(p["mix"], h, c, pos, cfg, kind, sh=sh)
    elif kind == "rglru":
        mix, c = R.rglru_block_decode(p["mix"], h, c, cfg, sh=sh)
    else:
        mix, c = S.ssd_decode(p["mix"], h, c, cfg, sh=sh)
    x = x + mix
    if kind == "ssd":
        return x, c
    h = L.rmsnorm(p["ln2"], x)
    if cfg.n_experts:
        y, _ = M.moe_apply(p["mlp"], h, cfg, sh=sh)
    else:
        y = L.mlp_apply(p["mlp"], h, cfg, sh=sh)
    return x + y, c


def decode_step(
    params,
    cache,
    batch: dict,
    pos: jnp.ndarray,
    cfg,
    sh: Callable = _id_sh,
    compute_dtype=jnp.bfloat16,
):
    """One decode step: batch {'tokens' (B,1)} or {'embeds' (B,1,D)}; pos scalar.

    Returns (logits (B,1,V...), new_cache).
    """
    if cfg.frontend == "audio_stub":
        x = batch["embeds"].astype(compute_dtype)
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    else:
        x = L.embed_apply(params["embed"], batch["tokens"], cfg).astype(compute_dtype)
    pattern = cfg.attn_pattern
    new_sb = []
    if cfg.n_superblocks:

        def body(x, inp):
            sb_params, sb_cache = inp
            new_c = []
            for j, kind in enumerate(pattern):
                x, cj = _decode_layer(sb_params[j], x, sb_cache[j], pos, cfg, kind, sh)
                new_c.append(cj)
            return x, tuple(new_c)

        x, new_sb = lax.scan(body, x, (params["superblocks"], cache["superblocks"]))

    new_rem = []
    for i, p in enumerate(params["remainder"]):
        kind = pattern[i % len(pattern)]
        x, ci = _decode_layer(p, x, cache["remainder"][i], pos, cfg, kind, sh)
        new_rem.append(ci)

    x = L.rmsnorm(params["final_norm"], x)
    logits = L.unembed_apply(params["embed"], x, cfg)
    if not isinstance(new_sb, tuple):
        new_sb = tuple(new_sb)
    return sh("logits", logits), {"superblocks": new_sb, "remainder": tuple(new_rem)}
