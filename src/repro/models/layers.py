"""Core layers: RMSNorm, RoPE, memory-bounded GQA attention, GLU MLPs.

All modules are functional pairs: `<mod>_defs(cfg) -> ParamDef pytree` and
`<mod>_apply(params, x, ...) -> y`.  Attention is computed in query blocks
(lax.scan + jax.checkpoint) so the S x S score matrix is never materialized
-- the XLA analogue of the Pallas flash kernel in repro/kernels (which is the
TPU production path; this is also its oracle).

`sh(name, x)` is a sharding-constraint hook injected by the launcher
(identity by default) -- model code stays mesh-agnostic.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.parallel.spec import ParamDef

NEG_INF = -1e9


def _id_sh(name, x):
    return x


# ---------------------------------------------------------------- RMSNorm
def rmsnorm_defs(dim: int) -> dict:
    return {"scale": ParamDef((dim,), (None,), init="zeros")}  # (1 + scale) form


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps) * (1.0 + p["scale"].astype(jnp.float32))
    return y.astype(dt)


# ------------------------------------------------------------------ RoPE
def rope_freqs(head_dim: int, base: float) -> np.ndarray:
    return base ** (-np.arange(0, head_dim, 2, dtype=np.float64) / head_dim)


def apply_rope(x: jnp.ndarray, pos: jnp.ndarray, base: float) -> jnp.ndarray:
    """x: (..., S, H, hd); pos: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, base), jnp.float32)  # (hd/2,)
    ang = pos.astype(jnp.float32)[..., None, None] * freqs  # (..., S, 1, hd/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------- Attention
def attention_defs(cfg) -> dict:
    # "embed_attn" lets the rule table fully shard attention weights over
    # (data, model) when head counts cannot TP-shard (DESIGN.md SS6).
    d, H, Kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    defs = {
        "wq": ParamDef((d, H, hd), ("embed_attn", "heads", None), fan_in=d),
        "wk": ParamDef((d, Kv, hd), ("embed_attn", "kv", None), fan_in=d),
        "wv": ParamDef((d, Kv, hd), ("embed_attn", "kv", None), fan_in=d),
        "wo": ParamDef((H, hd, d), ("heads", None, "embed_attn"), fan_in=H * hd),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((H, hd), ("heads", None), init="zeros")
        defs["bk"] = ParamDef((Kv, hd), ("kv", None), init="zeros")
        defs["bv"] = ParamDef((Kv, hd), ("kv", None), init="zeros")
    if cfg.qk_norm:
        defs["q_norm"] = rmsnorm_defs(hd)["scale"]
        defs["k_norm"] = rmsnorm_defs(hd)["scale"]
    return defs


def _qkv(p, x, cfg, kind, pos):
    """Project + rope; returns q (B,S,Kv,G,hd), k, v (B,S,Kv,hd)."""
    H, Kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = rmsnorm({"scale": p["q_norm"]}, q)
        k = rmsnorm({"scale": p["k_norm"]}, k)
    base = cfg.rope_base_local if kind == "local" else cfg.rope_base_global
    q = apply_rope(q, pos, base)
    k = apply_rope(k, pos, base)
    q = q.reshape(*q.shape[:2], Kv, H // Kv, hd)
    return q, k, v


def _block_attend(qb, k, v, q_pos, k_pos, cfg, kind):
    """One query block vs full keys. qb:(B,QB,Kv,G,hd) k/v:(B,T,Kv,hd)."""
    scale = cfg.head_dim ** -0.5
    logits = jnp.einsum("bqkgh,btkh->bkgqt", qb, k).astype(jnp.float32) * scale
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    mask = k_pos[None, :] <= q_pos[:, None]
    if kind == "local" and cfg.window > 0:
        mask &= k_pos[None, :] > (q_pos[:, None] - cfg.window)
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(qb.dtype)
    return jnp.einsum("bkgqt,btkh->bqkgh", probs, v)


def attention_apply(p, x, cfg, kind, sh: Callable = _id_sh, pos_offset: int = 0):
    """Full-sequence (train / prefill) attention, q-chunked."""
    B, S, D = x.shape
    H, Kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    pos = pos_offset + jnp.arange(S, dtype=jnp.int32)[None]  # (1, S)
    q, k, v = _qkv(p, x, cfg, kind, pos)
    q = sh("q", q)
    # under sequence-parallel attention, gather the (narrow) k/v heads over
    # seq rather than letting SPMD gather the full-width residual
    k, v = sh("kv_full", k), sh("kv_full", v)
    QB = min(cfg.attn_q_block, S)
    nb = -(-S // QB)
    pad = nb * QB - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    qb = jnp.moveaxis(q.reshape(B, nb, QB, Kv, H // Kv, hd), 1, 0)
    k_pos = pos[0]

    @jax.checkpoint
    def blk(carry, inp):
        qi, i = inp
        q_pos = pos_offset + i * QB + jnp.arange(QB, dtype=jnp.int32)
        return carry, _block_attend(qi, k, v, q_pos, k_pos, cfg, kind)

    if nb == 1:
        out = _block_attend(qb[0], k, v, k_pos, k_pos, cfg, kind)
    else:
        _, out = lax.scan(blk, 0, (qb, jnp.arange(nb)))
        out = jnp.moveaxis(out, 0, 1).reshape(B, nb * QB, Kv, H // Kv, hd)[:, :S]
    out = out.reshape(B, S, H, hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def attention_decode(p, x, cache, pos, cfg, kind, sh: Callable = _id_sh):
    """Single-token decode against a (possibly ring) KV cache.

    cache: dict(k=(B,T,Kv,hd), v=..., pos scalar passed separately).
    For local layers T == window (ring buffer); global layers T == max_len.
    """
    B = x.shape[0]
    H, Kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    posv = jnp.full((1, 1), pos, jnp.int32)
    q, k_new, v_new = _qkv(p, x, cfg, kind, posv)
    T = cache["k"].shape[1]
    slot = (pos % T).astype(jnp.int32) if kind == "local" else pos.astype(jnp.int32)
    ck = lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
    cv = lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))
    ck, cv = sh("cache_k", ck), sh("cache_v", cv)
    idx = jnp.arange(T, dtype=jnp.int32)
    if kind == "local":
        # ring: slot i holds position pos - ((pos - i) mod T)
        k_pos = pos - ((pos - idx) % T)
        valid = (k_pos >= 0) & (k_pos <= pos)
    else:
        k_pos = idx
        valid = idx <= pos
    scale = hd ** -0.5
    qh = q[:, 0]  # (B,Kv,G,hd)
    logits = jnp.einsum("bkgh,btkh->bkgt", qh, ck.astype(qh.dtype)).astype(jnp.float32) * scale
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    logits = jnp.where(valid[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(qh.dtype)
    out = jnp.einsum("bkgt,btkh->bkgh", probs, cv.astype(qh.dtype))
    out = out.reshape(B, 1, H, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, {"k": ck, "v": cv}


# ------------------------------------------------------------------- MLP
def mlp_defs(cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamDef((d, f), ("embed", "ffn")),
        "w_up": ParamDef((d, f), ("embed", "ffn")),
        "w_down": ParamDef((f, d), ("ffn", "embed")),
    }


def mlp_apply(p, x, cfg, sh: Callable = _id_sh):
    act = jax.nn.gelu if cfg.mlp == "geglu" else jax.nn.silu
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    h = sh("ffn", act(g) * u)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))


# ------------------------------------------------------------ Embeddings
def embed_defs(cfg) -> dict:
    defs = {"tok": ParamDef((cfg.vocab_padded, cfg.d_model), ("vocab", "embed"), init="embed")}
    if not cfg.tie_embeddings:
        defs["unembed"] = ParamDef(
            (cfg.n_io_heads, cfg.d_model, cfg.vocab_padded), (None, "embed", "vocab")
        )
    return defs


def embed_apply(p, tokens, cfg):
    e = jnp.take(p["tok"], tokens, axis=0)
    return e * jnp.asarray(np.sqrt(cfg.d_model), e.dtype)


def unembed_apply(p, x, cfg):
    """x (B,S,D) -> logits (B,S,V) or (B,S,heads,V); pad vocab masked."""
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, p["tok"].astype(x.dtype))
        if cfg.n_io_heads > 1:
            logits = jnp.repeat(logits[:, :, None], cfg.n_io_heads, axis=2)
    else:
        logits = jnp.einsum("bsd,hdv->bshv", x, p["unembed"].astype(x.dtype))
        if cfg.n_io_heads == 1:
            logits = logits[:, :, 0]
    if cfg.vocab_padded != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab_size
        logits = jnp.where(pad_mask, NEG_INF, logits)
    return logits
