"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060), chunked dual form.

Forward (train/prefill) uses the block-decomposition: within a chunk of Q
steps the SSD operator is an attention-like quadratic form with decay masks;
across chunks a small recurrence carries the (H, P, N) state.  Decode is the
O(1) recurrence h = exp(dt·a)·h + dt·(x ⊗ B);  y = C·h + D·x.

Layout: d_inner = expand·d_model, H = d_inner/headdim heads of size P,
G state groups of size N (B/C shared across heads within a group).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel.spec import ParamDef


def _id_sh(name, x):
    return x


def ssd_defs(cfg) -> dict:
    d = cfg.d_model
    di, g, n, h = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_heads
    cw = cfg.conv_width
    conv_dim = di + 2 * g * n
    return {
        "w_in": ParamDef((d, 2 * di + 2 * g * n + h), ("embed", "rnn")),
        "conv_w": ParamDef((cw, conv_dim), ("conv", "rnn"), init="small"),
        "conv_b": ParamDef((conv_dim,), ("rnn",), init="zeros"),
        "a_log": ParamDef((h,), (None,), init="zeros"),  # a = -exp(a_log) = -1
        "d_skip": ParamDef((h,), (None,), init="ones"),
        "dt_bias": ParamDef((h,), (None,), init="zeros"),
        "norm": ParamDef((di,), (None,), init="zeros"),
        "w_out": ParamDef((di, d), ("rnn", "embed")),
    }


def _split_in(p, x, cfg):
    """x (B,S,D) -> z (B,S,di), conv_in (B,S,di+2gn), dt_raw (B,S,H)."""
    di, g, n, h = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_heads
    proj = jnp.einsum("bsd,dk->bsk", x, p["w_in"].astype(x.dtype))
    z = proj[..., :di]
    conv_in = proj[..., di : di + di + 2 * g * n]
    dt_raw = proj[..., di + di + 2 * g * n :]
    return z, conv_in, dt_raw


def _conv(u, conv_w, conv_b, state=None):
    cw = conv_w.shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype)
    else:
        pad = state.astype(u.dtype)
    up = jnp.concatenate([pad, u], axis=1)
    y = sum(
        up[:, i : i + u.shape[1]] * conv_w[i].astype(u.dtype) for i in range(cw)
    ) + conv_b.astype(u.dtype)
    return jax.nn.silu(y), up[:, -(cw - 1) :]


def _gated_norm(p, y, z, eps=1e-6):
    yf = (y * jax.nn.silu(z)).astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return (yf * lax.rsqrt(var + eps) * (1.0 + p["norm"].astype(jnp.float32))).astype(y.dtype)


def ssd_apply(p, x, cfg, sh: Callable = _id_sh):
    """Full-sequence chunked SSD. x:(B,S,D) -> (B,S,D)."""
    B, S, D = x.shape
    di, G, N, H = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_heads
    P = cfg.ssm_headdim
    Q = min(cfg.ssm_chunk, S)
    assert S % Q == 0, f"seq {S} must divide ssm_chunk {Q}"
    nc = S // Q

    z, conv_in, dt_raw = _split_in(p, x, cfg)
    u, _ = _conv(conv_in, p["conv_w"], p["conv_b"])
    xh = u[..., :di].reshape(B, S, H, P)
    Bm = u[..., di : di + G * N].reshape(B, S, G, N)
    Cm = u[..., di + G * N :].reshape(B, S, G, N)
    # broadcast groups over heads
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2)  # (B,S,H,N)
    Ch = jnp.repeat(Cm, rep, axis=2)
    # (§Perf ssm-1, refuted: forcing head-sharding of the SSD core moved the
    # reshard points without reducing bytes — SPMD propagation already
    # head-parallelizes the chunk scan; constraints reverted.)

    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (H,)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    da = dt * a  # (B,S,H)

    # chunk views
    xq = xh.reshape(B, nc, Q, H, P).astype(jnp.float32)
    Bq = Bh.reshape(B, nc, Q, H, N).astype(jnp.float32)
    Cq = Ch.reshape(B, nc, Q, H, N).astype(jnp.float32)
    dtq = dt.reshape(B, nc, Q, H)
    daq = da.reshape(B, nc, Q, H)
    cum = jnp.cumsum(daq, axis=2)  # (B,nc,Q,H)

    # --- intra-chunk (quadratic, attention-like with decay mask)
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Qi,Qj,H)
    ii = jnp.arange(Q)
    causal = ii[:, None] >= ii[None, :]
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(rel), 0.0)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Cq, Bq) * decay * dtq[:, :, None, :, :]
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", scores, xq)

    # --- chunk summary states and inter-chunk recurrence
    last = cum[:, :, -1:, :]  # (B,nc,1,H)
    wts = jnp.exp(last - cum) * dtq  # (B,nc,Q,H)
    S_c = jnp.einsum("bcqh,bcqhn,bcqhp->bchnp", wts, Bq, xq)  # (B,nc,H,N,P)
    chunk_decay = jnp.exp(last[:, :, 0])  # (B,nc,H)

    def step(h, inp):
        s_c, dec = inp  # (B,H,N,P), (B,H)
        h_new = h * dec[..., None, None] + s_c
        return h_new, h  # emit state *before* this chunk

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    _, h_prev = lax.scan(
        step,
        h0,
        (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)  # (B,nc,H,N,P) state entering chunk c

    y_off = jnp.einsum("bcqhn,bchnp->bcqhp", Cq * jnp.exp(cum)[..., None], h_prev)
    y = (y_diag + y_off).reshape(B, S, H, P)
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = sh("rnn", y.astype(x.dtype).reshape(B, S, di))
    y = _gated_norm(p, y, z)
    return jnp.einsum("bsk,kd->bsd", y, p["w_out"].astype(x.dtype))


def ssd_decode(p, x, state, cfg, sh: Callable = _id_sh):
    """One-step decode. state = {h:(B,H,N,P) fp32, conv:(B,cw-1,conv_dim)}."""
    B = x.shape[0]
    di, G, N, H = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_heads
    P = cfg.ssm_headdim
    z, conv_in, dt_raw = _split_in(p, x, cfg)
    u, conv_state = _conv(conv_in, p["conv_w"], p["conv_b"], state["conv"])
    xh = u[:, 0, :di].reshape(B, H, P).astype(jnp.float32)
    Bm = u[:, 0, di : di + G * N].reshape(B, G, N)
    Cm = u[:, 0, di + G * N :].reshape(B, G, N)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)  # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)

    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # (B,H)
    dec = jnp.exp(dt * a)  # (B,H)
    h = state["h"] * dec[..., None, None] + jnp.einsum(
        "bh,bhn,bhp->bhnp", dt, Bh, xh
    )
    y = jnp.einsum("bhn,bhnp->bhp", Ch, h)  # (B,H,P)
    y = y + p["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = _gated_norm(p, y, z)
    out = jnp.einsum("bsk,kd->bsd", y, p["w_out"].astype(x.dtype))
    return out, {"h": h, "conv": conv_state.astype(state["conv"].dtype)}


def ssd_init_state(cfg, batch: int, dtype=jnp.bfloat16):
    di, G, N, H = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_heads
    return {
        "h": jnp.zeros((batch, H, N, cfg.ssm_headdim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di + 2 * G * N), dtype),
    }
