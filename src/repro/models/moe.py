"""Mixture-of-Experts layer with four routers:

  topk_aux   — standard softmax top-k + Switch-style load-balancing aux loss
               (the baseline the paper's KG corresponds to: router's
               preference is followed regardless of load).
  pkg_potc   — PARTIAL KEY GROUPING routing (the paper's technique as a
               first-class MoE feature): for each of the k slots, the token's
               two candidate experts are its next-two ranked experts; the
               token goes to the *less loaded* candidate, where load is a
               running token count maintained per token block (batch-greedy
               local estimation, DESIGN.md §2).  Balance is structural, so no
               aux loss and far fewer capacity drops.
  d_choices  — skew-adaptive candidate counts (arXiv 1510.05714): an online
               SPACESAVING summary of *expert popularity* (keys = the
               router's top-ranked expert per token, tracked in the scan
               carry by core.estimation.online_head_tables) widens hot
               experts' tokens to d(e) <= router_d_max candidate lanes out of
               their d_max router-ranked experts; cold-expert tokens keep the
               exact 2-choice PKG step.
  w_choices  — same summary with any_worker=True: tokens preferring a *head*
               expert spill to ANY expert via the capacity-aware water-fill
               global argmin, so a hot-expert token flood spreads over the
               emptiest experts.  Tail tokens use the same rank pairs as
               pkg_potc (an all-tail stream is bit-identical to it).

The d/w modes route through kernels.ref.ref_moe_adaptive_dispatch — the host
twin of the Pallas kernels.moe_adaptive_dispatch, both built on
kernels/route_core.py — so the layer, the kernel, and the oracle share ONE
choose implementation (differentiable w.r.t. the gate values; routing indices
carry no gradients, as in pkg_potc).

Dispatch is capacity-based (GShard layout): tokens are scattered to
(E, C, d) buffers, expert-GEMM'd, and combined with the (renormalized) gate
weights.  Experts shard over the "model" axis (EP) when divisible, else the
d_ff dim shards (TP-experts, e.g. mixtral's 8 experts on a 16-way axis).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.estimation import online_head_tables
from repro.parallel.spec import ParamDef


def _id_sh(name, x):
    return x


def moe_defs(cfg) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamDef((d, E), ("embed", None), init="small"),
        "w_gate": ParamDef((E, d, f), ("experts", "embed", "ffn")),
        "w_up": ParamDef((E, d, f), ("experts", "embed", "ffn")),
        "w_down": ParamDef((E, f, d), ("experts", "ffn", "embed")),
    }


def _pkg_choose(cand, cgate, n_experts: int, block: int):
    """Block-greedy PoTC over candidate pairs.

    cand:(T,k,2) int32 expert ids, cgate:(T,k,2) gates. Processes tokens in
    blocks; within a block loads are stale (paper §3.2 local estimation).
    Returns (idx (T,k), gates (T,k)).
    """
    T, k, _ = cand.shape
    nblk = -(-T // block)
    pad = nblk * block - T
    cand_p = jnp.pad(cand, ((0, pad), (0, 0), (0, 0)))
    gate_p = jnp.pad(cgate, ((0, pad), (0, 0), (0, 0)))
    cand_b = cand_p.reshape(nblk, block, k, 2)
    gate_b = gate_p.reshape(nblk, block, k, 2)

    def step(loads, inp):
        c, g = inp  # (block,k,2)
        lc = loads[c]  # (block,k,2)
        sel = jnp.argmin(lc, axis=-1)  # ties -> first (higher-gate) candidate
        idx = jnp.take_along_axis(c, sel[..., None], axis=-1)[..., 0]
        gsel = jnp.take_along_axis(g, sel[..., None], axis=-1)[..., 0]
        hist = jax.nn.one_hot(idx.reshape(-1), n_experts, dtype=jnp.int32).sum(0)
        return loads + hist, (idx, gsel)

    loads0 = jnp.zeros((n_experts,), jnp.int32)
    _, (idx, gates) = lax.scan(step, loads0, (cand_b, gate_b))
    return idx.reshape(-1, k)[:T], gates.reshape(-1, k)[:T]


def expert_head_tables(pref, n_experts: int, block: int, d_base: int = 2,
                       d_max: int = 4, capacity: int = 0,
                       any_worker: bool = False, min_count: int = 8):
    """Per-block EXPERT-popularity head tables for adaptive MoE routing.

    pref (T,) int32 is the stream of router-preferred (top-ranked) expert ids;
    the online SPACESAVING summary runs over it in a lax.scan carry
    (core.estimation.online_head_tables) and emits, per token block, the
    state *before* that block — head verdicts stale by at most `block`
    tokens, the same contract as the dispatch loads.  capacity=0 defaults to
    n_experts: the summary is then EXACT counts (at most E distinct keys).
    With any_worker=True head slots carry W_SENTINEL (consume with
    w_mode=True).  Returns (tbl_keys, tbl_ncand), each (T/block, capacity).
    """
    cap = capacity if capacity > 0 else n_experts
    return online_head_tables(
        pref, block, cap, n_experts, d=d_base, d_max=d_max,
        min_count=min_count, any_worker=any_worker,
    )


def _adaptive_choose(cand, cgate, n_experts: int, block: int, d_base: int,
                     d_max: int, w_mode: bool, capacity: int = 0):
    """D-/W-Choices expert choice: the host path of the unified routing
    substrate.  cand/cgate (T, k, C) router-ranked candidates per slot.

    Builds expert-popularity head tables from the preferred-expert stream,
    then routes through kernels.ref.ref_moe_adaptive_dispatch — the same
    shared-core implementation the Pallas moe_adaptive_dispatch kernel is
    differentially tested against — so there is exactly one choose
    implementation to trust.  Differentiable w.r.t. cgate.  Returns
    (idx (T,k), gates (T,k)).
    """
    from repro.kernels.ref import ref_moe_adaptive_dispatch  # models on kernels

    T, k, C = cand.shape
    nblk = -(-T // block)
    pad = nblk * block - T
    # pad candidates with -1: they hash to no expert (empty one-hot /
    # zero histogram), miss the head table, and sit after every real token
    cand_p = jnp.pad(cand, ((0, pad), (0, 0), (0, 0)), constant_values=-1)
    gate_p = jnp.pad(cgate, ((0, pad), (0, 0), (0, 0)))
    pref = lax.stop_gradient(cand_p[:, 0, 0])
    tbl_k, tbl_n = expert_head_tables(
        pref, n_experts, block, d_base=d_base, d_max=d_max,
        capacity=capacity, any_worker=w_mode,
    )
    idx, gates, _ = ref_moe_adaptive_dispatch(
        cand_p, gate_p, tbl_k, tbl_n, n_experts,
        d_base=d_base, d_max=d_max, block=block, w_mode=w_mode,
    )
    return idx[:T], gates[:T]


def route(p, x2d, cfg):
    """x2d (T,d) -> (idx (T,k), gates (T,k), aux_loss scalar)."""
    T = x2d.shape[0]
    E, k = cfg.n_experts, cfg.top_k
    logits = jnp.einsum("td,de->te", x2d, p["router"].astype(x2d.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    if cfg.router == "pkg_potc":
        topv, topi = lax.top_k(probs, 2 * k)
        cand = topi.reshape(T, k, 2).astype(jnp.int32)
        cgate = topv.reshape(T, k, 2)
        idx, gates = _pkg_choose(cand, cgate, E, cfg.pkg_block)
        aux = jnp.zeros((), jnp.float32)
    elif cfg.router in ("d_choices", "w_choices"):
        w_mode = cfg.router == "w_choices"
        # W-Choices keeps pkg_potc's rank pairs (all-tail == pkg_potc);
        # D-Choices widens to d_max ranked candidates per slot.
        d_max = 2 if w_mode else max(2, min(cfg.router_d_max, E // k))
        topv, topi = lax.top_k(probs, d_max * k)
        cand = topi.reshape(T, k, d_max).astype(jnp.int32)
        cgate = topv.reshape(T, k, d_max)
        idx, gates = _adaptive_choose(
            cand, cgate, E, cfg.pkg_block, 2, d_max, w_mode,
            capacity=cfg.router_ss_capacity,
        )
        aux = jnp.zeros((), jnp.float32)
    else:
        gates, idx = lax.top_k(probs, k)
        # Switch aux loss: E * sum_e f_e * P_e
        me = jnp.mean(probs, axis=0)  # (E,)
        assigned = jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(axis=(0, 1))
        fe = assigned / jnp.maximum(assigned.sum(), 1.0)
        aux = cfg.aux_loss_coef * E * jnp.sum(fe * me)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return idx.astype(jnp.int32), gates.astype(x2d.dtype), aux


def _positions_in_expert(flat_e, n_experts: int, block: int = 1024):
    """Rank of each assignment within its expert, via two-level blocked
    prefix sums.  A flat cumsum over T*k tokens lowers to an O(T·window)
    reduce-window on TPU (and dominates HLO flops at 1M tokens); blocking
    makes it O(T·block + (T/block)²) — §Perf iteration moe-1."""
    Tk = flat_e.shape[0]
    nb = -(-Tk // block)
    pad = nb * block - Tk
    fe = jnp.pad(flat_e, (0, pad), constant_values=n_experts)  # pad -> dummy
    oh = jax.nn.one_hot(fe, n_experts + 1, dtype=jnp.int32).reshape(
        nb, block, n_experts + 1
    )
    within = jnp.cumsum(oh, axis=1)  # (nb, block, E+1)
    block_tot = within[:, -1]  # (nb, E+1)
    offsets = jnp.cumsum(block_tot, axis=0) - block_tot  # exclusive block prefix
    pos = within - 1 + offsets[:, None, :]
    pos = jnp.take_along_axis(
        pos.reshape(nb * block, n_experts + 1), fe[:, None], axis=1
    )[:, 0]
    return pos[:Tk]


def moe_apply(p, x, cfg, sh: Callable = _id_sh):
    """x (B,S,D) -> (y (B,S,D), aux scalar).

    Dispatch is *grouped per batch row* (GShard groups): each sequence
    scatters its own S*k assignments into its own (E, C_row, d) buffer with
    C_row = cf*S*k/E.  With the batch dp-sharded, dispatch/combine are fully
    shard-local — no cross-device scatter or buffer gather (§Perf moe-3);
    the same locality argument as the paper's local load estimation.
    Routing itself stays global (token order), matching the paper's router.
    """
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    # (§Perf iteration moe-4, refuted: pre-gathering the sequence dim before
    # dispatch added traffic instead of localizing the scatter — reverted.)
    x2d = x.reshape(B * S, D)
    idx, gates, aux = route(p, x2d, cfg)  # (B*S, k)

    cap = max(int(cfg.capacity_factor * S * k / E + 0.5), 4)
    idx_r = idx.reshape(B, S * k)
    gates_r = gates.reshape(B, S * k)
    pos = jax.vmap(lambda fe: _positions_in_expert(fe, E))(idx_r)  # (B, S*k)
    keep = pos < cap
    slot = jnp.where(keep, idx_r * cap + pos, E * cap)  # overflow -> scratch row

    xk = jnp.repeat(x, k, axis=1) if k > 1 else x  # (B, S*k, D) token copies
    buf = jnp.zeros((B, E * cap + 1, D), x.dtype)
    buf = jax.vmap(lambda b, s, u: b.at[s].add(u))(buf, slot, xk)
    buf = sh("moe_buffer", buf[:, : E * cap].reshape(B, E, cap, D))

    act = jax.nn.gelu if cfg.mlp == "geglu" else jax.nn.silu
    g = jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(x.dtype))
    h = sh("moe_hidden", act(g) * u)
    out = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(x.dtype))

    out_flat = jnp.concatenate(
        [out.reshape(B, E * cap, D), jnp.zeros((B, 1, D), x.dtype)], axis=1
    )
    y = jnp.take_along_axis(out_flat, slot[..., None], axis=1)
    y = y * (gates_r * keep)[..., None].astype(x.dtype)
    y = y.reshape(B, S, k, D).sum(axis=2) if k > 1 else y.reshape(B, S, D)
    return y, aux


def expert_load_stats(idx, n_experts: int):
    """Diagnostics: per-expert token counts + max/mean ratio (benchmarks)."""
    counts = jnp.zeros((n_experts,), jnp.int32).at[idx.reshape(-1)].add(1)
    maxload = counts.max() / jnp.maximum(counts.mean(), 1e-9)
    return counts, maxload
