"""Mixture-of-Experts layer with two routers:

  topk_aux  — standard softmax top-k + Switch-style load-balancing aux loss
              (the baseline the paper's KG corresponds to: router's preference
              is followed regardless of load).
  pkg_potc  — PARTIAL KEY GROUPING routing (the paper's technique as a
              first-class MoE feature): for each of the k slots, the token's
              two candidate experts are its next-two ranked experts; the token
              goes to the *less loaded* candidate, where load is a running
              token count maintained per token block (batch-greedy local
              estimation, DESIGN.md §2).  Balance is structural, so no aux
              loss and far fewer capacity drops.

Dispatch is capacity-based (GShard layout): tokens are scattered to
(E, C, d) buffers, expert-GEMM'd, and combined with the (renormalized) gate
weights.  Experts shard over the "model" axis (EP) when divisible, else the
d_ff dim shards (TP-experts, e.g. mixtral's 8 experts on a 16-way axis).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel.spec import ParamDef


def _id_sh(name, x):
    return x


def moe_defs(cfg) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamDef((d, E), ("embed", None), init="small"),
        "w_gate": ParamDef((E, d, f), ("experts", "embed", "ffn")),
        "w_up": ParamDef((E, d, f), ("experts", "embed", "ffn")),
        "w_down": ParamDef((E, f, d), ("experts", "ffn", "embed")),
    }


def _pkg_choose(cand, cgate, n_experts: int, block: int):
    """Block-greedy PoTC over candidate pairs.

    cand:(T,k,2) int32 expert ids, cgate:(T,k,2) gates. Processes tokens in
    blocks; within a block loads are stale (paper §3.2 local estimation).
    Returns (idx (T,k), gates (T,k)).
    """
    T, k, _ = cand.shape
    nblk = -(-T // block)
    pad = nblk * block - T
    cand_p = jnp.pad(cand, ((0, pad), (0, 0), (0, 0)))
    gate_p = jnp.pad(cgate, ((0, pad), (0, 0), (0, 0)))
    cand_b = cand_p.reshape(nblk, block, k, 2)
    gate_b = gate_p.reshape(nblk, block, k, 2)

    def step(loads, inp):
        c, g = inp  # (block,k,2)
        lc = loads[c]  # (block,k,2)
        sel = jnp.argmin(lc, axis=-1)  # ties -> first (higher-gate) candidate
        idx = jnp.take_along_axis(c, sel[..., None], axis=-1)[..., 0]
        gsel = jnp.take_along_axis(g, sel[..., None], axis=-1)[..., 0]
        hist = jax.nn.one_hot(idx.reshape(-1), n_experts, dtype=jnp.int32).sum(0)
        return loads + hist, (idx, gsel)

    loads0 = jnp.zeros((n_experts,), jnp.int32)
    _, (idx, gates) = lax.scan(step, loads0, (cand_b, gate_b))
    return idx.reshape(-1, k)[:T], gates.reshape(-1, k)[:T]


def route(p, x2d, cfg):
    """x2d (T,d) -> (idx (T,k), gates (T,k), aux_loss scalar)."""
    T = x2d.shape[0]
    E, k = cfg.n_experts, cfg.top_k
    logits = jnp.einsum("td,de->te", x2d, p["router"].astype(x2d.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    if cfg.router == "pkg_potc":
        topv, topi = lax.top_k(probs, 2 * k)
        cand = topi.reshape(T, k, 2).astype(jnp.int32)
        cgate = topv.reshape(T, k, 2)
        idx, gates = _pkg_choose(cand, cgate, E, cfg.pkg_block)
        aux = jnp.zeros((), jnp.float32)
    else:
        gates, idx = lax.top_k(probs, k)
        # Switch aux loss: E * sum_e f_e * P_e
        me = jnp.mean(probs, axis=0)  # (E,)
        assigned = jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(axis=(0, 1))
        fe = assigned / jnp.maximum(assigned.sum(), 1.0)
        aux = cfg.aux_loss_coef * E * jnp.sum(fe * me)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return idx.astype(jnp.int32), gates.astype(x2d.dtype), aux


def _positions_in_expert(flat_e, n_experts: int, block: int = 1024):
    """Rank of each assignment within its expert, via two-level blocked
    prefix sums.  A flat cumsum over T*k tokens lowers to an O(T·window)
    reduce-window on TPU (and dominates HLO flops at 1M tokens); blocking
    makes it O(T·block + (T/block)²) — §Perf iteration moe-1."""
    Tk = flat_e.shape[0]
    nb = -(-Tk // block)
    pad = nb * block - Tk
    fe = jnp.pad(flat_e, (0, pad), constant_values=n_experts)  # pad -> dummy
    oh = jax.nn.one_hot(fe, n_experts + 1, dtype=jnp.int32).reshape(
        nb, block, n_experts + 1
    )
    within = jnp.cumsum(oh, axis=1)  # (nb, block, E+1)
    block_tot = within[:, -1]  # (nb, E+1)
    offsets = jnp.cumsum(block_tot, axis=0) - block_tot  # exclusive block prefix
    pos = within - 1 + offsets[:, None, :]
    pos = jnp.take_along_axis(
        pos.reshape(nb * block, n_experts + 1), fe[:, None], axis=1
    )[:, 0]
    return pos[:Tk]


def moe_apply(p, x, cfg, sh: Callable = _id_sh):
    """x (B,S,D) -> (y (B,S,D), aux scalar).

    Dispatch is *grouped per batch row* (GShard groups): each sequence
    scatters its own S*k assignments into its own (E, C_row, d) buffer with
    C_row = cf*S*k/E.  With the batch dp-sharded, dispatch/combine are fully
    shard-local — no cross-device scatter or buffer gather (§Perf moe-3);
    the same locality argument as the paper's local load estimation.
    Routing itself stays global (token order), matching the paper's router.
    """
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    # (§Perf iteration moe-4, refuted: pre-gathering the sequence dim before
    # dispatch added traffic instead of localizing the scatter — reverted.)
    x2d = x.reshape(B * S, D)
    idx, gates, aux = route(p, x2d, cfg)  # (B*S, k)

    cap = max(int(cfg.capacity_factor * S * k / E + 0.5), 4)
    idx_r = idx.reshape(B, S * k)
    gates_r = gates.reshape(B, S * k)
    pos = jax.vmap(lambda fe: _positions_in_expert(fe, E))(idx_r)  # (B, S*k)
    keep = pos < cap
    slot = jnp.where(keep, idx_r * cap + pos, E * cap)  # overflow -> scratch row

    xk = jnp.repeat(x, k, axis=1) if k > 1 else x  # (B, S*k, D) token copies
    buf = jnp.zeros((B, E * cap + 1, D), x.dtype)
    buf = jax.vmap(lambda b, s, u: b.at[s].add(u))(buf, slot, xk)
    buf = sh("moe_buffer", buf[:, : E * cap].reshape(B, E, cap, D))

    act = jax.nn.gelu if cfg.mlp == "geglu" else jax.nn.silu
    g = jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(x.dtype))
    h = sh("moe_hidden", act(g) * u)
    out = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(x.dtype))

    out_flat = jnp.concatenate(
        [out.reshape(B, E * cap, D), jnp.zeros((B, 1, D), x.dtype)], axis=1
    )
    y = jnp.take_along_axis(out_flat, slot[..., None], axis=1)
    y = y * (gates_r * keep)[..., None].astype(x.dtype)
    y = y.reshape(B, S, k, D).sum(axis=2) if k > 1 else y.reshape(B, S, D)
    return y, aux


def expert_load_stats(idx, n_experts: int):
    """Diagnostics: per-expert token counts + max/mean ratio (benchmarks)."""
    counts = jnp.zeros((n_experts,), jnp.int32).at[idx.reshape(-1)].add(1)
    maxload = counts.max() / jnp.maximum(counts.mean(), 1e-9)
    return counts, maxload
