"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block:  x -> [W_gate -> GeLU]  ⊙  [W_x -> causal depthwise conv1d -> RG-LRU] -> W_out
Cell:   r_t = σ(W_a u_t + b_a)          (recurrence gate)
        i_t = σ(W_i u_t + b_i)          (input gate)
        log a_t = -c · softplus(Λ) · r_t,  c = 8
        h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ u_t)

Training uses lax.associative_scan over the sequence (log-space products for
stability); decode is the O(1) single-step update.  Gate projections are full
(d_rnn × d_rnn) dense (the reference impl uses block-diagonal-per-head; dense
is a strict superset, noted in DESIGN.md).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel.spec import ParamDef

_C = 8.0


def _id_sh(name, x):
    return x


def rglru_defs(cfg) -> dict:
    d, w, cw = cfg.d_model, cfg.rnn_width, cfg.conv_width
    return {
        "w_x": ParamDef((d, w), ("embed", "rnn")),
        "w_gate": ParamDef((d, w), ("embed", "rnn")),
        "conv_w": ParamDef((cw, w), ("conv", "rnn"), init="small"),
        "conv_b": ParamDef((w,), ("rnn",), init="zeros"),
        "gate_a": ParamDef((w, w), ("rnn", None), init="small"),
        "gate_a_b": ParamDef((w,), (None,), init="zeros"),
        "gate_i": ParamDef((w, w), ("rnn", None), init="small"),
        "gate_i_b": ParamDef((w,), (None,), init="zeros"),
        "lam": ParamDef((w,), (None,), init="ones"),
        "w_out": ParamDef((w, d), ("rnn", "embed")),
    }


def _causal_conv(u, conv_w, conv_b, state=None):
    """Depthwise causal conv, width cw. u:(B,S,w). state:(B,cw-1,w) or None."""
    cw = conv_w.shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype)
    else:
        pad = state.astype(u.dtype)
    up = jnp.concatenate([pad, u], axis=1)  # (B, S+cw-1, w)
    y = sum(
        up[:, i : i + u.shape[1]] * conv_w[i].astype(u.dtype) for i in range(cw)
    ) + conv_b.astype(u.dtype)
    new_state = up[:, -(cw - 1) :] if cw > 1 else pad
    return y, new_state


def _gates(p, u):
    r = jax.nn.sigmoid(
        jnp.einsum("bsw,wv->bsv", u, p["gate_a"].astype(u.dtype))
        + p["gate_a_b"].astype(u.dtype)
    ).astype(jnp.float32)
    i = jax.nn.sigmoid(
        jnp.einsum("bsw,wv->bsv", u, p["gate_i"].astype(u.dtype))
        + p["gate_i_b"].astype(u.dtype)
    ).astype(jnp.float32)
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r  # (B,S,w)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = beta * (i * u.astype(jnp.float32))
    return log_a, b


def rglru_scan(p, u):
    """u:(B,S,w) -> h:(B,S,w): h_t = a_t h_{t-1} + b_t via associative scan."""
    log_a, b = _gates(p, u)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 + a2, jnp.exp(a2) * b1 + b2

    la, hb = lax.associative_scan(combine, (log_a, b), axis=1)
    return hb.astype(u.dtype)


def rglru_block_apply(p, x, cfg, sh: Callable = _id_sh):
    """Full-sequence (train/prefill) recurrent block."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate"].astype(x.dtype)))
    u = jnp.einsum("bsd,dw->bsw", x, p["w_x"].astype(x.dtype))
    u, _ = _causal_conv(u, p["conv_w"], p["conv_b"])
    u = sh("rnn", jax.nn.silu(u))
    h = rglru_scan(p, u)
    return jnp.einsum("bsw,wd->bsd", h * gate, p["w_out"].astype(x.dtype))


def rglru_block_decode(p, x, state, cfg, sh: Callable = _id_sh):
    """One-step decode. state = {h:(B,w) fp32, conv:(B,cw-1,w)}."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate"].astype(x.dtype)))
    u = jnp.einsum("bsd,dw->bsw", x, p["w_x"].astype(x.dtype))
    u, conv_state = _causal_conv(u, p["conv_w"], p["conv_b"], state["conv"])
    u = jax.nn.silu(u)
    log_a, b = _gates(p, u)  # (B,1,w)
    h = jnp.exp(log_a[:, 0]) * state["h"] + b[:, 0]  # (B,w) fp32
    y = (h[:, None].astype(x.dtype)) * gate
    out = jnp.einsum("bsw,wd->bsd", y, p["w_out"].astype(x.dtype))
    return out, {"h": h, "conv": conv_state.astype(state["conv"].dtype)}


def rglru_init_state(cfg, batch: int, dtype=jnp.bfloat16):
    w, cw = cfg.rnn_width, cfg.conv_width
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cw - 1, w), dtype),
    }
