from repro.models.transformer import (
    init_defs,
    init_params,
    forward,
    loss_fn,
    init_cache,
    decode_step,
)
