"""Three-term roofline model from compiled dry-run artifacts (TPU v5e target).

  compute    = HLO_FLOPs_per_device / peak_FLOPs          (197 TF/s bf16)
  memory     = HLO_bytes_per_device / HBM_bw              (819 GB/s)
  collective = collective_bytes_per_device / link_bw      (~50 GB/s/link ICI)

`compiled.cost_analysis()` reports the per-device (post-SPMD) module, so its
flops/bytes are already per-chip.  Collective bytes are NOT in cost_analysis:
we parse the post-partitioning HLO and sum the (per-device) result-shape
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.  Cross-pod collectives (replica_groups spanning pods)
ride DCN; we report them separately with a 25 GB/s assumption.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

__all__ = ["HW", "collective_bytes", "roofline_report", "model_flops"]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12  # bf16 per chip
    hbm_bw: float = 819e9  # bytes/s
    ici_bw: float = 50e9  # bytes/s per link
    dcn_bw: float = 25e9  # bytes/s cross-pod


_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(sig: str, f32_bytes: int = 4) -> int:
    """Sum byte sizes of every typed shape in an HLO result signature.

    `f32_bytes=2` applies the bf16-wire correction: the XLA *CPU* backend
    (the dry-run host) legalizes every bf16 dot to f32, so activation
    collectives appear as f32 in host-compiled HLO even though the TPU-target
    program moves bf16.  Counting f32 at 2 B/elem recovers the intended wire
    size (fp32 master params are cast to bf16 before any gather — see
    train.loop — so no large intended-f32 collective remains).
    """
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        b = f32_bytes if dt == "f32" else _DTYPE_BYTES[dt]
        total += n * b
    return total


def collective_bytes(hlo_text: str, bf16_wire: bool = True) -> dict:
    """Per-device bytes moved by each collective kind (result-shape proxy)."""
    f32b = 2 if bf16_wire else 4
    out = {k: 0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # result line looks like:  %name = TYPE[dims] op-name(...)
        m = re.match(r"%?[\w.\-]+ = (.+?) ([\w\-]+)\(", s)
        if not m:
            continue
        sig, op = m.groups()
        for kind in _COLLECTIVES:
            if op == kind or op == kind + "-start" or op.startswith(kind):
                out[kind] += _shape_bytes(sig, f32b)
                count[kind] += 1
                break
    out = {k: v for k, v in out.items()}
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = count
    return out


def model_flops(cfg, shape_kind: str, tokens: int) -> float:
    """Useful model FLOPs: 6*N*D train, 2*N*D inference (N = active params)."""
    n = cfg.active_param_count()
    mult = 6.0 if shape_kind == "train" else 2.0
    return mult * n * tokens


def roofline_report(
    flops_per_device: float,
    hbm_bytes_per_device: float,
    coll_bytes_per_device: float,
    hw: HW = HW(),
    dcn_bytes_per_device: float = 0.0,
) -> dict:
    t_comp = flops_per_device / hw.peak_flops
    t_mem = hbm_bytes_per_device / hw.hbm_bw
    t_coll = coll_bytes_per_device / hw.ici_bw + dcn_bytes_per_device / hw.dcn_bw
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(t_comp, t_mem, t_coll)
    return {
        **terms,
        "dominant": dom.replace("_s", ""),
        "step_lower_bound_s": bound,
        # fraction of roofline if perfectly overlapped: useful-compute share
        "roofline_fraction": t_comp / bound if bound > 0 else 0.0,
    }
