"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun JSONs.

  PYTHONPATH=src python -m repro.roofline.report experiments/dryrun
"""
from __future__ import annotations

import json
import os
import sys


def load(outdir: str) -> list[dict]:
    rows = []
    for name in sorted(os.listdir(outdir)):
        if name.endswith(".json"):
            with open(os.path.join(outdir, name)) as f:
                rows.append(json.load(f))
    return rows


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table(rows: list[dict]) -> str:
    out = [
        "| arch | shape | mesh | compile s | args/dev | temp/dev | fits v5e | "
        "flops/dev | coll bytes/dev | AG | AR | RS | A2A |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        mem = r.get("memory", {})
        c = r["collectives"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['t_compile_s']} | "
            f"{fmt_bytes(mem.get('argument_bytes'))} | {fmt_bytes(mem.get('temp_bytes'))} | "
            f"{'Y' if r.get('fits_v5e') else '?'} | {r['flops_per_device']:.2e} | "
            f"{fmt_bytes(r['collective_bytes_per_device'])} | "
            f"{fmt_bytes(c['all-gather'])} | {fmt_bytes(c['all-reduce'])} | "
            f"{fmt_bytes(c['reduce-scatter'])} | {fmt_bytes(c['all-to-all'])} |"
        )
    return "\n".join(out)


def roofline_table(rows: list[dict]) -> str:
    out = [
        "| arch | shape | mesh | compute s | memory s | collective s | dominant | "
        "bound s | roofline frac | useful/HLO flops |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {rf['compute_s']:.3e} | "
            f"{rf['memory_s']:.3e} | {rf['collective_s']:.3e} | {rf['dominant']} | "
            f"{rf['step_lower_bound_s']:.3e} | {rf['roofline_fraction']:.3f} | "
            f"{r['useful_flops_ratio']:.2f} |"
        )
    return "\n".join(out)


def main() -> None:
    outdir = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    rows = load(outdir)
    sp = [r for r in rows if r["mesh"] == "16x16"]
    mp = [r for r in rows if r["mesh"] != "16x16"]
    print("## Dry-run (single-pod 16x16 = 256 chips)\n")
    print(dryrun_table(sp))
    print("\n## Dry-run (multi-pod 2x16x16 = 512 chips)\n")
    print(dryrun_table(mp))
    print("\n## Roofline (single-pod)\n")
    print(roofline_table(sp))
    print("\n## Roofline (multi-pod)\n")
    print(roofline_table(mp))
    # summary stats
    worst = sorted(sp, key=lambda r: r["roofline"]["roofline_fraction"])[:5]
    print("\nworst roofline fractions (single-pod):")
    for r in worst:
        print(
            f"  {r['arch']}/{r['shape']}: {r['roofline']['roofline_fraction']:.3f} "
            f"(dom {r['roofline']['dominant']})"
        )
    collbound = sorted(
        sp, key=lambda r: -r["roofline"]["collective_s"] / max(r["roofline"]["step_lower_bound_s"], 1e-12)
    )[:5]
    print("most collective-bound (single-pod):")
    for r in collbound:
        rf = r["roofline"]
        print(
            f"  {r['arch']}/{r['shape']}: coll {rf['collective_s']:.2e}s vs comp {rf['compute_s']:.2e}s"
        )


if __name__ == "__main__":
    main()
