from repro.roofline.analysis import (
    HW,
    collective_bytes,
    roofline_report,
    model_flops,
)
