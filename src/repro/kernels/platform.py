"""Platform detection for the Pallas kernels: ONE place decides interpret mode.

Every kernel entry point takes ``interpret: bool | None = None`` and resolves
it through `resolve_interpret`, so TPU runs compile natively by default while
CPU CI (and any other non-TPU backend) stays in interpreter mode — no caller
has to know which backend it is on, and no kernel can hard-code a default
that silently de-optimises TPU.  Pass an explicit bool to override (e.g. the
interpret-vs-compiled bit-exactness checks in bench_kernels.py).
"""
from __future__ import annotations

from typing import Optional

import jax

__all__ = ["interpret_default", "resolve_interpret"]


def interpret_default() -> bool:
    """True when Pallas must run in interpret mode (non-TPU backends)."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """None -> platform default (compile on TPU, interpret elsewhere)."""
    return interpret_default() if interpret is None else bool(interpret)
