"""THE shared block-routing core: one masked-greedy implementation for every
Pallas router and its host oracle.

Three consumers share this module verbatim (DESIGN.md SS3.3 "One routing-
kernel substrate"):

  kernels/pkg_route.py          — plain 2-choice PKG over hashed candidates
  kernels/adaptive_route.py     — D-/W-Choices with data-dependent candidate
                                  counts / per-block head-table snapshots
  kernels/moe_pkg_dispatch.py   — MoE expert dispatch (PKG-PoTC and the
                                  adaptive D-/W-Choices variants), where the
                                  "workers" are experts and each token block
                                  carries k slots of router-ranked candidates

plus every matching `ref_*` oracle in kernels/ref.py and the host router
modes in models/moe.py.  The kernel-side `route_block` speaks the TPU-native
formulation (one-hot-matmul load fetch + histogram update, no gathers); the
host-side `oracle_block_step` is the gather-based twin with identical mask /
sentinel / tie-break semantics.  Both import `waterfill_picks` and
`head_table_ncand` from here, so the W-sentinel water-fill and the head-table
lookup cannot drift between any kernel and any oracle — the bit-exactness
contracts in tests/test_kernels.py all reduce to this one module.

Vocabulary: `n_entities` is the number of routing targets — stream workers
for the routers, experts for MoE dispatch.  Loads are integer counts in f32
(IEEE-exact), the mask sentinel is 1e30 (greater than any reachable load),
and every argmin breaks ties to the lowest index.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.estimation import W_SENTINEL
from repro.core.hashing import splitmix32

__all__ = [
    "LANES",
    "MASK",
    "hash_candidates",
    "waterfill_picks",
    "head_table_ncand",
    "route_block",
    "oracle_block_step",
]

# Mask sentinel: 1e30 is > any reachable load and fp32-exact; kernels and
# oracles both read it from here so they stay bit-identical.
MASK = 1e30

LANES = 128  # VPU lane width the global reduction pads to


def hash_candidates(kb, seeds, n_entities: int):
    """SplitMix32 candidate ids for a block of keys: (V,) x (d,) -> (V, d).

    The d seeds come from core.hashing.derive_seeds; the family is
    prefix-stable in d, so a d_max-wide candidate table masked down to its
    first 2 lanes reproduces plain PKG's candidates exactly.
    """
    h = splitmix32(kb.astype(jnp.uint32)[:, None] ^ seeds[None, :])  # (V, d)
    return (h % jnp.uint32(n_entities)).astype(jnp.int32)


def waterfill_picks(loads, *, n_workers, block, inv_cap=None):
    """First `block` picks of sequential global-argmin routing from the
    (1, n_workers) loads row: pick r is where the r-th head message of a
    block goes, with every earlier pick's unit load accounted.

    Pick 0 is the masked global argmin — worker lanes padded to a LANES
    multiple with the MASK sentinel (pad lanes can never win the min),
    ties broken to the lowest worker index, exactly w_choices_partition's
    `jnp.argmin(loads)` step.  The full sequence needs no sequential loop:
    worker j's t-th pick happens at running load L_j + t, and "repeatedly
    take the min, add one" selects the multiset {(L_j + t, j) : t >= 0} in
    ascending (value, j) order — the block smallest entries of the
    (W_pad, block) value matrix flattened j-major, via lax.top_k on the
    negated values (top_k surfaces the lowest flat index first on ties, so
    ties land on the lowest worker, then ascending t, matching argmin's
    first-index rule at every step).  Loads are integer counts in f32, so
    values and ties are IEEE-exact; every oracle imports this function so
    kernel and oracle cannot drift.

    With `inv_cap` (a (1, n_workers) reciprocal-capacity row, arXiv
    1705.09073) the argmin runs over capacity-normalized values
    ``(L_j + t) / c_j`` — computed as ``(L_j + t) * inv_cap_j``, the SAME
    float product the sequential host scan forms, so block=1 stays
    bit-exact to the host and any block stays exact vs the oracle (shared
    code).  The multiset argument is unchanged: values still increase
    strictly in t for every worker (inv_cap > 0).  A uniform inv_cap of
    1.0 multiplies exactly and reproduces the unweighted picks bit-for-bit.

    Returns picks (block,) int32 worker ids.
    """
    pad = -n_workers % LANES
    row = loads
    icap = inv_cap
    if pad:
        row = jnp.concatenate(
            [row, jnp.full((1, pad), MASK, jnp.float32)], axis=1
        )
        if icap is not None:
            icap = jnp.concatenate(
                [icap, jnp.ones((1, pad), jnp.float32)], axis=1
            )
    t = jnp.arange(block, dtype=jnp.float32)
    vals = row.reshape(n_workers + pad, 1) + t[None, :]  # (W_pad, B): (j, t)
    if icap is not None:
        vals = vals * icap.reshape(n_workers + pad, 1)
    _, idx = lax.top_k(-vals.reshape(-1), block)  # ties -> j-major
    return (idx // block).astype(jnp.int32)


def head_table_ncand(kb, tk, tn, d_base, d_max):
    """Per-lane candidate count from a head-table snapshot: (V, H) equality
    compare + masked max (no gather); a miss or a tail hit yields d_base.
    A W_SENTINEL table entry (any_worker head tables) passes through
    unclipped, flagging the global-argmin path to route_block."""
    hit = kb[:, None] == tk[None, :]  # (V, H)
    nc = jnp.max(jnp.where(hit, tn, 0), axis=1)  # (V,) 0 on miss
    clipped = jnp.clip(jnp.where(nc > 0, nc, d_base), d_base, d_max)
    return jnp.where(nc == jnp.int32(W_SENTINEL), nc, clipped)


def _mask_and_flag(lc, nc, d_max: int, w_mode: bool):
    """Shared mask step: candidate lane j of a row participates iff
    j < nc (W-sentinel rows keep all d_max tail lanes live under w_mode, the
    global pick overrides below).  nc=None means every lane participates
    (plain fixed-d routing) — no mask is materialised at all."""
    if nc is None:
        return lc, None
    is_w = nc == jnp.int32(W_SENTINEL)
    nc_tail = jnp.where(is_w, d_max, nc) if w_mode else nc
    col = jnp.arange(d_max, dtype=jnp.int32)
    return jnp.where(col[None, :] < nc_tail[:, None], lc, jnp.float32(MASK)), is_w


def route_block(cand, nc, loads, *, n_entities, w_mode, inv_cap=None):
    """The kernel-side masked-greedy routing core for one vector block.

    cand (V, d_max) int32 candidate entity ids, nc (V,) int32 candidate
    counts (or None: all d_max lanes live), loads (1, n_entities) f32.
    Returns (choice (V,), sel (V,), is_w (V,) or None, new loads).  `sel` is
    the winning candidate column (MoE dispatch gathers the matching gate with
    it); `is_w` flags the lanes the W path overrode (their `sel` is
    meaningless).  Every Pallas router calls this — the callers differ ONLY
    in how cand/nc are produced — so sentinel/tie-break/update semantics
    cannot drift apart.

    Loads are fetched and written back MXU-style: one-hot(cand) @ loads for
    the candidate lookup, ones @ one-hot(choice) for the histogram update —
    no gathers or scatters (DESIGN.md SS2/SS7).

    `inv_cap` (optional (1, n_entities) f32 reciprocal-capacity row) makes
    every comparison capacity-normalized: the fetch reads the normalized
    row ``loads * inv_cap`` and the water-fill receives inv_cap, while the
    CARRY stays the raw integer-count histogram (the +1 update is exact and
    capacity only ever rescales comparisons).  inv_cap=None skips the
    multiply entirely — the program is unchanged — and a uniform row of 1.0
    multiplies exactly, so both are bit-identical to the unweighted kernel.

    With w_mode (static), lanes with nc == W_SENTINEL take the W-Choices
    path: the r-th such lane of the block gets the r-th water-fill argmin of
    the block-start loads row (waterfill_picks), so consecutive head
    messages spread exactly as the sequential global-argmin would.  Tail
    lanes still read block-start loads only — the same < block staleness
    contract as the load vector itself (DESIGN.md SS2).  w_mode=False skips
    the reduction entirely for callers that never emit the sentinel;
    sentinel-free streams route identically either way.
    """
    V, d_max = cand.shape
    eid = jnp.arange(n_entities, dtype=jnp.int32)
    onehot_c = (cand[..., None] == eid).astype(jnp.float32)  # (V, d_max, n)
    row = loads if inv_cap is None else loads * inv_cap
    lc = jax.lax.dot_general(
        onehot_c.reshape(V * d_max, n_entities),
        row.reshape(n_entities, 1),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(V, d_max)
    lc, is_w = _mask_and_flag(lc, nc, d_max, w_mode)
    sel = jnp.argmin(lc, axis=-1)  # (V,) ties -> first candidate
    choice = jnp.take_along_axis(cand, sel[:, None], axis=-1)[:, 0]
    if w_mode:
        # W path: head rank within the block -> water-fill pick, fetched with
        # a one-hot matmul (gather-free, DESIGN.md SS7; picks < n_entities
        # are f32-exact).  rank < V always: at most V head lanes precede.
        rank = jnp.cumsum(is_w.astype(jnp.int32)) - is_w  # (V,)
        picks = waterfill_picks(
            loads, n_workers=n_entities, block=V, inv_cap=inv_cap
        )
        lane = jnp.arange(V, dtype=jnp.int32)
        onehot_r = (rank[:, None] == lane[None, :]).astype(jnp.float32)  # (V, V)
        head_choice = jax.lax.dot_general(
            onehot_r,
            picks.astype(jnp.float32).reshape(V, 1),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).reshape(V).astype(jnp.int32)
        choice = jnp.where(is_w, head_choice, choice)
    hist = (choice[:, None] == eid).astype(jnp.float32).sum(axis=0)
    return choice, sel, is_w, loads + hist[None, :]


def oracle_block_step(loads, cand, nc, *, n_entities, w_mode, inv_cap=None):
    """The host-side (gather-based) twin of route_block — one vector block of
    the masked batch-greedy, shared by every ref.py oracle and the host MoE
    router modes.  loads (n_entities,) f32, cand (V, d_max), nc (V,) or None,
    inv_cap (n_entities,) f32 reciprocal capacities or None.
    Returns (new_loads, choice, sel, is_w).

    The fetch is a plain gather (loads[cand]) and the W pick a plain indexed
    read — deliberately a DIFFERENT formulation from the kernel's one-hot
    matmuls, so the differential tests check the MXU formulation against
    straightforward indexing while the mask/sentinel/tie-break logic stays
    shared (same _mask_and_flag, same waterfill_picks)."""
    d_max = cand.shape[-1]
    row = loads if inv_cap is None else loads * inv_cap
    lc = row[cand]  # (V, d_max)
    lc, is_w = _mask_and_flag(lc, nc, d_max, w_mode)
    sel = jnp.argmin(lc, axis=-1)
    choice = jnp.take_along_axis(cand, sel[:, None], axis=-1)[:, 0]
    if w_mode:
        rank = jnp.cumsum(is_w.astype(jnp.int32)) - is_w
        picks = waterfill_picks(
            loads[None, :], n_workers=n_entities, block=cand.shape[0],
            inv_cap=None if inv_cap is None else inv_cap[None, :],
        )
        choice = jnp.where(is_w, picks[rank], choice)
    hist = jax.nn.one_hot(choice, n_entities, dtype=jnp.float32).sum(0)
    return loads + hist, choice, sel, is_w
