"""Pallas TPU kernel: fused RMSNorm (row tiles, fp32 accumulation)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.platform import resolve_interpret


def _kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)  # (BR, D)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * (1.0 + w_ref[...].astype(jnp.float32))
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "eps", "interpret"))
def rmsnorm(
    x: jnp.ndarray,
    w: jnp.ndarray,
    block_rows: int = 256,
    eps: float = 1e-6,
    interpret: Optional[bool] = None,
):
    """x (..., D), w (D,) -> same shape; rows tiled in blocks of block_rows.

    interpret=None resolves via kernels.platform (compile on TPU, interpret
    elsewhere)."""
    shape = x.shape
    D = shape[-1]
    x2 = x.reshape(-1, D)
    R = x2.shape[0]
    br = min(block_rows, R)
    pad = (-R) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=((R + pad) // br,),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=resolve_interpret(interpret),
    )(x2, w)
    return out[:R].reshape(shape)
