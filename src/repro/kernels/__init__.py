"""Pallas TPU kernels for the compute hot-spots (pl.pallas_call + BlockSpec),
with jnp oracles in ref.py and jit'd wrappers in ops.py.  On CPU they run in
interpret mode (correctness); on TPU they compile natively."""
from repro.kernels import ref
from repro.kernels.ops import (
    adaptive_route,
    adaptive_route_online,
    flash_attention,
    interpret_mode,
    moe_adaptive_dispatch,
    moe_pkg_dispatch,
    pkg_route,
    rmsnorm,
    w_route,
)
