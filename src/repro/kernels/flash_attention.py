"""Pallas TPU kernel: blockwise online-softmax attention (FlashAttention).

Forward-only (serving/prefill path; training uses the q-chunked XLA oracle in
models/layers.py).  Supports causal masking, sliding windows, and GQA (the kv
head for q-head h is h // (H/Kv), resolved in the BlockSpec index maps).

Grid (B, H, nQ, nK): the innermost kv dimension accumulates into VMEM scratch
(acc (BQ,hd) fp32, running max m and sum l (BQ,1)); the output block is
finalized at the last kv step.  Fully-masked kv blocks are skipped via
pl.when on the block indices (causal: j_lo > q_hi; window: j_hi < q_lo - w).

MXU alignment: BQ = BK = 128 defaults; hd is padded by the compiler when not
a multiple of 128 (e.g. danube's hd=80).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.platform import resolve_interpret

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, bq, bk, n_k_blocks, causal, window, scale, seq_off):
    i = pl.program_id(2)  # q block
    j = pl.program_id(3)  # kv block

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # block-level positions: q rows are offset by seq_off (q covers the last
    # S positions of the T keys)
    q_lo = i * bq + seq_off
    q_hi = q_lo + bq - 1
    j_lo = j * bk
    j_hi = j_lo + bk - 1
    live = True
    if causal:
        live = j_lo <= q_hi
    if window > 0:
        live = jnp.logical_and(live, j_hi > q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # (BQ, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (BK, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (BQ, BK)
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = j_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > (q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]  # (BQ,1)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(j == n_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, :, 0, :] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "bq", "bk", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    window: int = 0,
    bq: int = 128,
    bk: int = 128,
    interpret: Optional[bool] = None,
):
    """q (B,S,H,hd), k/v (B,T,Kv,hd) -> (B,S,H,hd).

    S and T must divide by bq / bk.  q positions are aligned to the *end* of
    the key range (q row s has absolute position s + T - S).  interpret=None
    resolves via kernels.platform (compile on TPU, interpret elsewhere).
    """
    B, S, H, hd = q.shape
    T, Kv = k.shape[1], k.shape[2]
    assert S % bq == 0 and T % bk == 0, (S, T, bq, bk)
    G = H // Kv
    grid = (B, H, S // bq, T // bk)
    kern = functools.partial(
        _kernel,
        bq=bq,
        bk=bk,
        n_k_blocks=T // bk,
        causal=causal,
        window=window,
        scale=hd ** -0.5,
        seq_off=T - S,
    )
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, i, j: (b, j, h // G, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, i, j: (b, j, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(q, k, v)
    return out
