"""Pallas TPU kernel: adaptive multi-choice stream router (D-/W-Choices).

Same batch-greedy skeleton as pkg_route.py (one program per chunk, VMEM load
vector, vector blocks of V lanes) but the number of candidates is
*data-dependent per key*: the router consumes a second int32 array
n_cand (N,) with values in [1, d_max] (produced by the SPACESAVING head
tracker, DESIGN.md SS3.3).  All d_max hashes are always computed and padded
into the one-hot matmul — the TPU-native formulation of DESIGN.md SS2/SS7 is
preserved — and candidates j >= n_cand[i] are masked to +MASK before the
lane-wise argmin, so tail keys (n_cand == 2) reproduce plain PKG bit-exactly.

W-CHOICES ("head goes anywhere", arXiv 1510.05714) is in-kernel too: with
the static opt-in w_mode=True (set by the W-named wrappers below), a key
whose n_cand equals estimation.W_SENTINEL skips the hashed-candidate argmin
and routes by a *global* masked argmin over the full (1, n_workers) loads row
(pad lanes hold the MASK sentinel, ties break to the lowest worker index), so
n_workers need not be a power of two nor fit one VPU lane group.  The r-th
head lane of a block takes the r-th argmin of the sequential water-fill of
that row — computed loop-free by one stable sort (waterfill_picks) — so head
messages reproduce w_choices_partition's global step exactly from block-start
loads instead of piling a whole block onto a single stale minimum.

The per-block machinery (hash, one-hot load fetch, mask, argmin, water-fill,
histogram update) all lives in kernels/route_core.py — ONE routing core
shared with pkg_route.py, moe_pkg_dispatch.py, and every ref.py oracle —
this module only wires chunk/block iteration and the head-table plumbing
around route_core.route_block:

  hash   : SplitMix32 over (key ^ seed_j), j < d_max      (VPU int ops)
  lookup : one-hot(cand) @ loads                          (MXU matmul)
  mask   : lane j participates iff j < n_cand             (VPU select)
  choose : lane-wise argmin over d_max masked candidates,
           or water-fill global argmin over all n_workers
           lanes when n_cand == W_SENTINEL                (lane reduction)
  update : loads += ones @ one-hot(choice)                (MXU matmul)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.estimation import W_SENTINEL
from repro.core.hashing import derive_seeds
from repro.kernels.platform import resolve_interpret
from repro.kernels.route_core import (
    hash_candidates,
    head_table_ncand,
    route_block,
    waterfill_picks,
)

# Long-standing private names, re-exported for existing importers (tests,
# ref.py): the implementations moved verbatim to route_core.
_waterfill_picks = waterfill_picks
_head_table_ncand = head_table_ncand


def _kernel(keys_ref, ncand_ref, seeds_ref, *rest, n_workers, d_max, block,
            w_mode, has_cap):
    if has_cap:
        icap_ref, assign_ref, loads_ref = rest
        icap = icap_ref[...]  # (1, n_workers) f32 reciprocal capacities
    else:
        assign_ref, loads_ref = rest
        icap = None
    chunk = keys_ref.shape[0]
    nblk = chunk // block
    seeds = seeds_ref[...]  # (d_max,) uint32

    def body(i, loads):  # loads (1, n_workers) f32
        kb = keys_ref[pl.ds(i * block, block)]  # (V,)
        nc = ncand_ref[pl.ds(i * block, block)]  # (V,)
        cand = hash_candidates(kb, seeds, n_workers)  # (V, d_max)
        choice, _, _, loads = route_block(
            cand, nc, loads, n_entities=n_workers, w_mode=w_mode,
            inv_cap=icap,
        )
        assign_ref[pl.ds(i * block, block)] = choice
        return loads

    loads = lax.fori_loop(0, nblk, body, jnp.zeros((1, n_workers), jnp.float32))
    loads_ref[...] = loads


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_workers", "d_max", "seed", "chunk", "block", "interpret", "w_mode"
    ),
)
def adaptive_route(
    keys: jnp.ndarray,
    n_cand: jnp.ndarray,
    n_workers: int,
    d_max: int = 4,
    seed: int = 0,
    chunk: int = 1024,
    block: int = 128,
    interpret: Optional[bool] = None,
    w_mode: bool = False,
    capacities: Optional[jnp.ndarray] = None,
):
    """Route keys (N,) int32 with per-key candidate counts n_cand (N,).

    n_cand values are in [1, d_max]; with w_mode=True a value of W_SENTINEL
    routes that key to the globally least-loaded worker (W-Choices; see
    w_route for the flag-based wrapper, which sets w_mode itself).  Returns
    (assign (N,), per-chunk loads (N/chunk, n_workers)).  N must divide by
    chunk; chunk by block.  interpret=None resolves via kernels.platform
    (compile on TPU, interpret elsewhere).  The default w_mode=False keeps
    the sentinel check and the water-fill reduction out of the inner loop —
    D-Choices callers never emit the sentinel and pay nothing; sentinel-free
    streams route bit-identically under both settings.

    `capacities` (optional (n_workers,) strictly positive weights) routes on
    capacity-normalized loads (route_core inv_cap row, arXiv 1705.09073):
    both the masked candidate argmin AND the W water-fill compare
    loads * (1/c).  None leaves the program unchanged; uniform capacities
    are bit-exact to it.
    """
    N = keys.shape[0]
    assert N % chunk == 0 and chunk % block == 0, (N, chunk, block)
    grid = (N // chunk,)
    has_cap = capacities is not None
    kern = functools.partial(
        _kernel, n_workers=n_workers, d_max=d_max, block=block, w_mode=w_mode,
        has_cap=has_cap,
    )
    in_specs = [
        pl.BlockSpec((chunk,), lambda i: (i,)),
        pl.BlockSpec((chunk,), lambda i: (i,)),
        pl.BlockSpec((d_max,), lambda i: (0,)),
    ]
    operands = [
        keys.astype(jnp.int32), n_cand.astype(jnp.int32),
        derive_seeds(seed, d_max),
    ]
    if has_cap:
        icap = 1.0 / jnp.asarray(capacities, jnp.float32).reshape(1, n_workers)
        in_specs.append(pl.BlockSpec((1, n_workers), lambda i: (0, 0)))
        operands.append(icap)
    assign, loads = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec((1, n_workers), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N,), jnp.int32),
            jax.ShapeDtypeStruct((N // chunk, n_workers), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(*operands)
    return assign, loads


# ---------------------------------------------------------------------------
# Online variant: head table refreshed between vector blocks (DESIGN.md SS3.3
# "Online estimation").  The tracker itself runs upstream
# (core.estimation.online_head_tables, one lax.scan over blocks); the kernel
# consumes its per-block snapshots as a device-resident operand — table b is
# the summary state *before* block b, so head verdicts are stale by at most
# `block` messages, the same contract as the stale loads of
# pkg_partition_batched.  In-kernel the lookup is a (V, H) equality compare +
# masked max (VPU only, no gather): a miss or a tail hit both yield d_base
# candidates, i.e. exact PKG routing.
# ---------------------------------------------------------------------------


def _kernel_online(keys_ref, tblk_ref, tbln_ref, seeds_ref, *rest, n_workers,
                   d_base, d_max, block, w_mode, has_cap):
    if has_cap:
        icap_ref, assign_ref, loads_ref = rest
        icap = icap_ref[...]  # (1, n_workers) f32 reciprocal capacities
    else:
        assign_ref, loads_ref = rest
        icap = None
    chunk = keys_ref.shape[0]
    nblk = chunk // block
    seeds = seeds_ref[...]  # (d_max,) uint32
    H = tblk_ref.shape[1]

    def body(i, loads):  # loads (1, n_workers) f32
        kb = keys_ref[pl.ds(i * block, block)]  # (V,) int32
        tk = tblk_ref[pl.ds(i, 1), :].reshape(H)  # (H,) int32 head-table keys
        tn = tbln_ref[pl.ds(i, 1), :].reshape(H)  # (H,) int32 head-table d(k)
        nc = head_table_ncand(kb, tk, tn, d_base, d_max)
        cand = hash_candidates(kb, seeds, n_workers)  # (V, d_max)
        choice, _, _, loads = route_block(
            cand, nc, loads, n_entities=n_workers, w_mode=w_mode,
            inv_cap=icap,
        )
        assign_ref[pl.ds(i * block, block)] = choice
        return loads

    loads = lax.fori_loop(0, nblk, body, jnp.zeros((1, n_workers), jnp.float32))
    loads_ref[...] = loads


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_workers", "d_base", "d_max", "seed", "chunk", "block", "interpret",
        "w_mode",
    ),
)
def adaptive_route_online(
    keys: jnp.ndarray,
    tbl_keys: jnp.ndarray,
    tbl_ncand: jnp.ndarray,
    n_workers: int,
    d_base: int = 2,
    d_max: int = 8,
    seed: int = 0,
    chunk: int = 1024,
    block: int = 128,
    interpret: Optional[bool] = None,
    w_mode: bool = False,
    capacities: Optional[jnp.ndarray] = None,
):
    """Route keys (N,) against per-block head tables (N/block, H).

    tbl_keys/tbl_ncand come from core.estimation.online_head_tables(block=...)
    with the same `block`; H is the tracker capacity.  Keys absent from their
    block's table (or present with ncand == d_base) route exactly as PKG.
    Tables emitted with any_worker=True carry W_SENTINEL for head slots, which
    routes those keys through the in-kernel global argmin (online W-Choices) —
    pass w_mode=True (static) with such tables; the default w_mode=False keeps
    the water-fill reduction out of the loop for sentinel-free D-Choices
    tables (a sentinel met without w_mode degrades to d_max candidates).
    Returns (assign (N,), per-chunk loads (N/chunk, n_workers)).
    """
    N = keys.shape[0]
    H = tbl_keys.shape[1]
    assert N % chunk == 0 and chunk % block == 0, (N, chunk, block)
    assert tbl_keys.shape == (N // block, H) == tbl_ncand.shape
    grid = (N // chunk,)
    has_cap = capacities is not None
    kern = functools.partial(
        _kernel_online, n_workers=n_workers, d_base=d_base, d_max=d_max,
        block=block, w_mode=w_mode, has_cap=has_cap,
    )
    blocks_per_chunk = chunk // block
    in_specs = [
        pl.BlockSpec((chunk,), lambda i: (i,)),
        pl.BlockSpec((blocks_per_chunk, H), lambda i: (i, 0)),
        pl.BlockSpec((blocks_per_chunk, H), lambda i: (i, 0)),
        pl.BlockSpec((d_max,), lambda i: (0,)),
    ]
    operands = [
        keys.astype(jnp.int32),
        tbl_keys.astype(jnp.int32),
        tbl_ncand.astype(jnp.int32),
        derive_seeds(seed, d_max),
    ]
    if has_cap:
        icap = 1.0 / jnp.asarray(capacities, jnp.float32).reshape(1, n_workers)
        in_specs.append(pl.BlockSpec((1, n_workers), lambda i: (0, 0)))
        operands.append(icap)
    assign, loads = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec((1, n_workers), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N,), jnp.int32),
            jax.ShapeDtypeStruct((N // chunk, n_workers), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(*operands)
    return assign, loads


# ---------------------------------------------------------------------------
# W-Choices entry point: per-key head flags instead of candidate counts.
# ---------------------------------------------------------------------------


def w_route(
    keys: jnp.ndarray,
    is_head: jnp.ndarray,
    n_workers: int,
    d: int = 2,
    seed: int = 0,
    chunk: int = 1024,
    block: int = 128,
    interpret: Optional[bool] = None,
    capacities: Optional[jnp.ndarray] = None,
):
    """W-Choices Pallas router: head keys (is_head != 0) go to the globally
    least-loaded worker via the in-kernel global argmin; tail keys take PKG's
    exact d-candidate step.  is_head (N,) is any int/bool array (e.g. from
    SpaceSavingTracker.head_counts); with block=1 and chunk=N this reproduces
    core.partitioners.w_choices_partition bit-exactly given the same head set
    (the differential contract in tests/test_kernels.py).  `capacities`
    weights both the tail argmin and the head water-fill by 1/c (see
    adaptive_route); the block=1 contract extends to the capacity-weighted
    host scan.

    Returns (assign (N,), per-chunk loads (N/chunk, n_workers)).
    """
    flags = jnp.asarray(is_head).astype(jnp.int32)
    n_cand = jnp.where(flags != 0, jnp.int32(W_SENTINEL), jnp.int32(d))
    return adaptive_route(
        keys, n_cand, n_workers, d_max=d, seed=seed, chunk=chunk, block=block,
        interpret=interpret, w_mode=True, capacities=capacities,
    )
