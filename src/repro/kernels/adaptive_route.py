"""Pallas TPU kernel: adaptive multi-choice stream router (D-/W-Choices).

Same batch-greedy skeleton as pkg_route.py (one program per chunk, VMEM load
vector, vector blocks of V lanes) but the number of candidates is
*data-dependent per key*: the router consumes a second int32 array
n_cand (N,) with values in [1, d_max] (produced by the SPACESAVING head
tracker, DESIGN.md SS3.3).  All d_max hashes are always computed and padded
into the one-hot matmul — the TPU-native formulation of DESIGN.md SS2/SS7 is
preserved — and candidates j >= n_cand[i] are masked to +BIG before the
lane-wise argmin, so tail keys (n_cand == 2) reproduce plain PKG bit-exactly.

  hash   : SplitMix32 over (key ^ seed_j), j < d_max      (VPU int ops)
  lookup : one-hot(cand) @ loads                          (MXU matmul)
  mask   : lane j participates iff j < n_cand             (VPU select)
  choose : lane-wise argmin over d_max masked candidates
  update : loads += ones @ one-hot(choice)                (MXU matmul)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.hashing import derive_seeds, splitmix32

# Mask sentinel: 1e30 is > any reachable load and fp32-exact; ref.py uses the
# same literal so kernel and oracle stay bit-identical.


def _route_block(kb, nc, seeds, loads, *, n_workers, d_max, block):
    """The shared masked-greedy routing core for one vector block.

    kb (V,) int32 keys, nc (V,) int32 candidate counts, loads (1, n) f32.
    Returns (choice (V,) int32, new loads).  Both kernels call this — the
    per-key-ncand and the head-table variants differ ONLY in how nc is
    produced — so sentinel/tie-break/update semantics cannot drift apart.
    """
    wid = jnp.arange(n_workers, dtype=jnp.int32)
    col = jnp.arange(d_max, dtype=jnp.int32)
    h = splitmix32(kb.astype(jnp.uint32)[:, None] ^ seeds[None, :])  # (V, d_max)
    cand = (h % jnp.uint32(n_workers)).astype(jnp.int32)  # (V, d_max)
    onehot_c = (cand[..., None] == wid).astype(jnp.float32)  # (V, d_max, n)
    lc = jax.lax.dot_general(
        onehot_c.reshape(block * d_max, n_workers),
        loads.reshape(n_workers, 1),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(block, d_max)
    lc = jnp.where(col[None, :] < nc[:, None], lc, 1e30)
    sel = jnp.argmin(lc, axis=-1)  # (V,)
    choice = jnp.take_along_axis(cand, sel[:, None], axis=-1)[:, 0]
    hist = (choice[:, None] == wid).astype(jnp.float32).sum(axis=0)
    return choice, loads + hist[None, :]


def _kernel(keys_ref, ncand_ref, seeds_ref, assign_ref, loads_ref, *,
            n_workers, d_max, block):
    chunk = keys_ref.shape[0]
    nblk = chunk // block
    seeds = seeds_ref[...]  # (d_max,) uint32

    def body(i, loads):  # loads (1, n_workers) f32
        kb = keys_ref[pl.ds(i * block, block)]  # (V,)
        nc = ncand_ref[pl.ds(i * block, block)]  # (V,)
        choice, loads = _route_block(
            kb, nc, seeds, loads, n_workers=n_workers, d_max=d_max, block=block
        )
        assign_ref[pl.ds(i * block, block)] = choice
        return loads

    loads = lax.fori_loop(0, nblk, body, jnp.zeros((1, n_workers), jnp.float32))
    loads_ref[...] = loads


@functools.partial(
    jax.jit,
    static_argnames=("n_workers", "d_max", "seed", "chunk", "block", "interpret"),
)
def adaptive_route(
    keys: jnp.ndarray,
    n_cand: jnp.ndarray,
    n_workers: int,
    d_max: int = 4,
    seed: int = 0,
    chunk: int = 1024,
    block: int = 128,
    interpret: bool = True,
):
    """Route keys (N,) int32 with per-key candidate counts n_cand (N,).

    Returns (assign (N,), per-chunk loads (N/chunk, n_workers)).
    N must divide by chunk; chunk by block.  interpret=True on CPU.
    """
    N = keys.shape[0]
    assert N % chunk == 0 and chunk % block == 0, (N, chunk, block)
    grid = (N // chunk,)
    kern = functools.partial(_kernel, n_workers=n_workers, d_max=d_max, block=block)
    assign, loads = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec((d_max,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec((1, n_workers), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N,), jnp.int32),
            jax.ShapeDtypeStruct((N // chunk, n_workers), jnp.float32),
        ],
        interpret=interpret,
    )(keys.astype(jnp.int32), n_cand.astype(jnp.int32), derive_seeds(seed, d_max))
    return assign, loads


# ---------------------------------------------------------------------------
# Online variant: head table refreshed between vector blocks (DESIGN.md SS3.3
# "Online estimation").  The tracker itself runs upstream
# (core.estimation.online_head_tables, one lax.scan over blocks); the kernel
# consumes its per-block snapshots as a device-resident operand — table b is
# the summary state *before* block b, so head verdicts are stale by at most
# `block` messages, the same contract as the stale loads of
# pkg_partition_batched.  In-kernel the lookup is a (V, H) equality compare +
# masked max (VPU only, no gather): a miss or a tail hit both yield d_base
# candidates, i.e. exact PKG routing.
# ---------------------------------------------------------------------------


def _head_table_ncand(kb, tk, tn, d_base, d_max):
    """Per-lane candidate count from a head-table snapshot: (V, H) equality
    compare + masked max (no gather); a miss or a tail hit yields d_base."""
    hit = kb[:, None] == tk[None, :]  # (V, H)
    nc = jnp.max(jnp.where(hit, tn, 0), axis=1)  # (V,) 0 on miss
    return jnp.clip(jnp.where(nc > 0, nc, d_base), d_base, d_max)


def _kernel_online(keys_ref, tblk_ref, tbln_ref, seeds_ref, assign_ref,
                   loads_ref, *, n_workers, d_base, d_max, block):
    chunk = keys_ref.shape[0]
    nblk = chunk // block
    seeds = seeds_ref[...]  # (d_max,) uint32
    H = tblk_ref.shape[1]

    def body(i, loads):  # loads (1, n_workers) f32
        kb = keys_ref[pl.ds(i * block, block)]  # (V,) int32
        tk = tblk_ref[pl.ds(i, 1), :].reshape(H)  # (H,) int32 head-table keys
        tn = tbln_ref[pl.ds(i, 1), :].reshape(H)  # (H,) int32 head-table d(k)
        nc = _head_table_ncand(kb, tk, tn, d_base, d_max)
        choice, loads = _route_block(
            kb, nc, seeds, loads, n_workers=n_workers, d_max=d_max, block=block
        )
        assign_ref[pl.ds(i * block, block)] = choice
        return loads

    loads = lax.fori_loop(0, nblk, body, jnp.zeros((1, n_workers), jnp.float32))
    loads_ref[...] = loads


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_workers", "d_base", "d_max", "seed", "chunk", "block", "interpret"
    ),
)
def adaptive_route_online(
    keys: jnp.ndarray,
    tbl_keys: jnp.ndarray,
    tbl_ncand: jnp.ndarray,
    n_workers: int,
    d_base: int = 2,
    d_max: int = 8,
    seed: int = 0,
    chunk: int = 1024,
    block: int = 128,
    interpret: bool = True,
):
    """Route keys (N,) against per-block head tables (N/block, H).

    tbl_keys/tbl_ncand come from core.estimation.online_head_tables(block=...)
    with the same `block`; H is the tracker capacity.  Keys absent from their
    block's table (or present with ncand == d_base) route exactly as PKG.
    Returns (assign (N,), per-chunk loads (N/chunk, n_workers)).
    """
    N = keys.shape[0]
    H = tbl_keys.shape[1]
    assert N % chunk == 0 and chunk % block == 0, (N, chunk, block)
    assert tbl_keys.shape == (N // block, H) == tbl_ncand.shape
    grid = (N // chunk,)
    kern = functools.partial(
        _kernel_online, n_workers=n_workers, d_base=d_base, d_max=d_max,
        block=block,
    )
    blocks_per_chunk = chunk // block
    assign, loads = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec((blocks_per_chunk, H), lambda i: (i, 0)),
            pl.BlockSpec((blocks_per_chunk, H), lambda i: (i, 0)),
            pl.BlockSpec((d_max,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec((1, n_workers), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N,), jnp.int32),
            jax.ShapeDtypeStruct((N // chunk, n_workers), jnp.float32),
        ],
        interpret=interpret,
    )(
        keys.astype(jnp.int32),
        tbl_keys.astype(jnp.int32),
        tbl_ncand.astype(jnp.int32),
        derive_seeds(seed, d_max),
    )
    return assign, loads
