"""Pallas TPU kernel: PKG stream router (batch-greedy Greedy-d).

Grid: one program per chunk of C keys.  Each program is an independent
local load estimator (paper §3.2): its (1, n_workers) fp32 load vector lives
in VMEM scratch and starts at zero.  Inside, keys are processed in vector
blocks of V lanes by the shared routing core (kernels/route_core.py — the
same route_block that powers adaptive_route.py and moe_pkg_dispatch.py,
called here with nc=None: every candidate lane live, no mask materialised):

  hash   : SplitMix32 over (key ^ seed_j) per choice j        (VPU int ops)
  lookup : one-hot(cand) @ loads                              (MXU matmul)
  choose : lane-wise argmin over d candidates
  update : loads += ones @ one-hot(choice)                    (MXU matmul)

Gathers/scatters are avoided entirely — candidate load lookup and the
histogram update are both expressed as one-hot matmuls, which is the
TPU-native formulation (DESIGN.md §2, §7).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.hashing import derive_seeds
from repro.kernels.platform import resolve_interpret
from repro.kernels.route_core import hash_candidates, route_block


def _kernel(keys_ref, seeds_ref, *rest, n_workers, d, block, has_cap):
    if has_cap:
        icap_ref, assign_ref, loads_ref = rest
        icap = icap_ref[...]  # (1, n_workers) f32 reciprocal capacities
    else:
        assign_ref, loads_ref = rest
        icap = None
    chunk = keys_ref.shape[0]
    nblk = chunk // block
    seeds = seeds_ref[...]  # (d,) uint32

    def body(i, loads):  # loads (1, n_workers) f32
        kb = keys_ref[pl.ds(i * block, block)]  # (V,)
        cand = hash_candidates(kb, seeds, n_workers)  # (V, d)
        choice, _, _, loads = route_block(
            cand, None, loads, n_entities=n_workers, w_mode=False,
            inv_cap=icap,
        )
        assign_ref[pl.ds(i * block, block)] = choice
        return loads

    loads = lax.fori_loop(0, nblk, body, jnp.zeros((1, n_workers), jnp.float32))
    loads_ref[...] = loads


@functools.partial(
    jax.jit, static_argnames=("n_workers", "d", "seed", "chunk", "block", "interpret")
)
def pkg_route(
    keys: jnp.ndarray,
    n_workers: int,
    d: int = 2,
    seed: int = 0,
    chunk: int = 1024,
    block: int = 128,
    interpret: Optional[bool] = None,
    capacities: Optional[jnp.ndarray] = None,
):
    """Route keys (N,) int32 -> (assign (N,), per-chunk loads (N/chunk, n)).

    N must divide by chunk; chunk by block.  interpret=None resolves via
    kernels.platform (compile on TPU, interpret elsewhere).  `capacities`
    (optional (n_workers,) strictly positive weights, arXiv 1705.09073) makes
    the candidate argmin capacity-normalized: the kernel receives a
    reciprocal-capacity row and compares loads * (1/c).  None routes the
    pre-capacity program unchanged; uniform capacities are bit-exact to it.
    """
    N = keys.shape[0]
    assert N % chunk == 0 and chunk % block == 0, (N, chunk, block)
    grid = (N // chunk,)
    has_cap = capacities is not None
    kern = functools.partial(
        _kernel, n_workers=n_workers, d=d, block=block, has_cap=has_cap
    )
    in_specs = [
        pl.BlockSpec((chunk,), lambda i: (i,)),
        pl.BlockSpec((d,), lambda i: (0,)),
    ]
    operands = [keys.astype(jnp.int32), derive_seeds(seed, d)]
    if has_cap:
        icap = 1.0 / jnp.asarray(capacities, jnp.float32).reshape(1, n_workers)
        in_specs.append(pl.BlockSpec((1, n_workers), lambda i: (0, 0)))
        operands.append(icap)
    assign, loads = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec((1, n_workers), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N,), jnp.int32),
            jax.ShapeDtypeStruct((N // chunk, n_workers), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(*operands)
    return assign, loads
