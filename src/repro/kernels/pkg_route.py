"""Pallas TPU kernel: PKG stream router (batch-greedy Greedy-d).

Grid: one program per chunk of C keys.  Each program is an independent
local load estimator (paper §3.2): its (1, n_workers) fp32 load vector lives
in VMEM scratch and starts at zero.  Inside, keys are processed in vector
blocks of V lanes:

  hash   : SplitMix32 over (key ^ seed_j) per choice j        (VPU int ops)
  lookup : one-hot(cand) @ loads                              (MXU matmul)
  choose : lane-wise argmin over d candidates
  update : loads += ones @ one-hot(choice)                    (MXU matmul)

Gathers/scatters are avoided entirely — candidate load lookup and the
histogram update are both expressed as one-hot matmuls, which is the
TPU-native formulation (DESIGN.md §2, §7).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.hashing import derive_seeds, splitmix32


def _kernel(keys_ref, seeds_ref, assign_ref, loads_ref, *, n_workers, d, block):
    chunk = keys_ref.shape[0]
    nblk = chunk // block
    seeds = seeds_ref[...]  # (d,) uint32
    wid = jnp.arange(n_workers, dtype=jnp.int32)

    def body(i, loads):  # loads (1, n_workers) f32
        kb = keys_ref[pl.ds(i * block, block)].astype(jnp.uint32)  # (V,)
        h = splitmix32(kb[:, None] ^ seeds[None, :])  # (V, d)
        cand = (h % jnp.uint32(n_workers)).astype(jnp.int32)  # (V, d)
        onehot_c = (cand[..., None] == wid).astype(jnp.float32)  # (V, d, n)
        lc = jax.lax.dot_general(
            onehot_c.reshape(block * d, n_workers),
            loads.reshape(n_workers, 1),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).reshape(block, d)
        sel = jnp.argmin(lc, axis=-1)  # (V,)
        choice = jnp.take_along_axis(cand, sel[:, None], axis=-1)[:, 0]
        assign_ref[pl.ds(i * block, block)] = choice
        hist = (choice[:, None] == wid).astype(jnp.float32).sum(axis=0)
        return loads + hist[None, :]

    loads = lax.fori_loop(0, nblk, body, jnp.zeros((1, n_workers), jnp.float32))
    loads_ref[...] = loads


@functools.partial(
    jax.jit, static_argnames=("n_workers", "d", "seed", "chunk", "block", "interpret")
)
def pkg_route(
    keys: jnp.ndarray,
    n_workers: int,
    d: int = 2,
    seed: int = 0,
    chunk: int = 1024,
    block: int = 128,
    interpret: bool = True,
):
    """Route keys (N,) int32 -> (assign (N,), per-chunk loads (N/chunk, n)).

    N must divide by chunk; chunk by block.  interpret=True on CPU.
    """
    N = keys.shape[0]
    assert N % chunk == 0 and chunk % block == 0, (N, chunk, block)
    grid = (N // chunk,)
    kern = functools.partial(_kernel, n_workers=n_workers, d=d, block=block)
    assign, loads = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec((1, n_workers), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N,), jnp.int32),
            jax.ShapeDtypeStruct((N // chunk, n_workers), jnp.float32),
        ],
        interpret=interpret,
    )(keys.astype(jnp.int32), derive_seeds(seed, d))
    return assign, loads
