"""Pallas TPU kernels: fused PKG expert choice for MoE dispatch — plain
2-choice PoTC (moe_pkg_dispatch) and the adaptive D-/W-Choices variant
(moe_adaptive_dispatch) that consumes per-block expert-popularity head tables.

Grid: one program per block of T_blk tokens; TPU grid steps run sequentially
on a core, so the (1, E) fp32 expert-load vector persists in VMEM scratch
across blocks — a single running local estimator, exactly the semantics of
models.moe._pkg_choose (intra-block-stale loads, paper §3.2).

Per block the k slots of every token flatten into blk*k routing lanes and go
through the SAME route_block core as the stream routers
(kernels/route_core.py): candidate loads are fetched with a one-hot matmul,
the lane-wise argmin picks the less-loaded candidate, and the block histogram
updates the load vector — no gathers or scatters.  The winning candidate
column (`sel`) gathers the matching gate weight.

The adaptive variant is the MoE incarnation of adaptive_route_online: each
block reads a head-table snapshot of the *expert-popularity* SPACESAVING
summary (keys = expert ids, emitted by models.moe.expert_head_tables /
core.estimation.online_head_tables over the stream of router-preferred
experts).  A token whose preferred expert is hot gets more candidate lanes
(D-Choices: d(e) of its d_max router-ranked experts) or, with w_mode=True and
W_SENTINEL table entries, spills to ANY expert via the capacity-aware
water-fill over the running loads row (W-Choices: consecutive head tokens
take consecutive global argmins, so a hot-expert token flood spreads over the
emptiest experts instead of piling onto one).  Spilled lanes keep their
slot's top-ranked gate weight (lane 0) — the router's confidence in the slot,
not in the arbitrary expert the flood landed on.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.platform import resolve_interpret
from repro.kernels.route_core import head_table_ncand, route_block


def _dispatch_block(cand, gate, nc, loads, *, n_experts, w_mode):
    """One token block through the shared core: flatten (blk, k, C) slot
    candidates into blk*k lanes, route, gather the winning gate per lane.
    Returns (idx (blk,k), gsel (blk,k), new loads)."""
    blk, k, C = cand.shape
    cand_f = cand.reshape(blk * k, C)
    gate_f = gate.reshape(blk * k, C)
    choice, sel, is_w, loads = route_block(
        cand_f, nc, loads, n_entities=n_experts, w_mode=w_mode
    )
    gsel = jnp.take_along_axis(gate_f, sel[:, None], axis=-1)[:, 0]
    if w_mode:
        # spilled lanes: sel is meaningless; keep the slot's top gate
        gsel = jnp.where(is_w, gate_f[:, 0], gsel)
    return choice.reshape(blk, k), gsel.reshape(blk, k), loads


def _kernel(cand_ref, gate_ref, idx_ref, gsel_ref, loads_ref, *, n_experts):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        loads_ref[...] = jnp.zeros_like(loads_ref)

    idx, gsel, loads = _dispatch_block(
        cand_ref[...], gate_ref[...], None, loads_ref[...],
        n_experts=n_experts, w_mode=False,
    )
    idx_ref[...] = idx
    gsel_ref[...] = gsel
    loads_ref[...] = loads


@functools.partial(jax.jit, static_argnames=("n_experts", "block", "interpret"))
def moe_pkg_dispatch(
    cand: jnp.ndarray,
    cgate: jnp.ndarray,
    n_experts: int,
    block: int = 256,
    interpret: Optional[bool] = None,
):
    """cand (T,k,2) int32, cgate (T,k,2) f32 -> (idx (T,k), gates (T,k), loads (E,)).

    T must divide by block.  interpret=None resolves via kernels.platform
    (compile on TPU, interpret elsewhere).
    """
    T, k, _ = cand.shape
    assert T % block == 0, (T, block)
    grid = (T // block,)
    kern = functools.partial(_kernel, n_experts=n_experts)
    idx, gsel, loads = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, k, 2), lambda i: (i, 0, 0)),
            pl.BlockSpec((block, k, 2), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block, k), lambda i: (i, 0)),
            pl.BlockSpec((block, k), lambda i: (i, 0)),
            pl.BlockSpec((1, n_experts), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, k), jnp.int32),
            jax.ShapeDtypeStruct((T, k), cgate.dtype),
            jax.ShapeDtypeStruct((1, n_experts), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(cand.astype(jnp.int32), cgate)
    return idx, gsel, loads[0]


def _kernel_adaptive(cand_ref, gate_ref, tblk_ref, tbln_ref, idx_ref,
                     gsel_ref, loads_ref, *, n_experts, d_base, d_max, w_mode):
    blk, k, _ = cand_ref.shape
    H = tblk_ref.shape[1]

    @pl.when(pl.program_id(0) == 0)
    def _init():
        loads_ref[...] = jnp.zeros_like(loads_ref)

    cand = cand_ref[...]  # (blk, k, d_max)
    tk = tblk_ref[...].reshape(H)  # (H,) expert ids in this block's snapshot
    tn = tbln_ref[...].reshape(H)  # (H,) d(e) / W_SENTINEL per head expert
    # head verdict is per TOKEN, keyed by its preferred (top-ranked) expert,
    # then broadcast over the token's k slots
    pref = cand[:, 0, 0]  # (blk,)
    nc_tok = head_table_ncand(pref, tk, tn, d_base, d_max)  # (blk,)
    nc = jnp.broadcast_to(nc_tok[:, None], (blk, k)).reshape(blk * k)
    idx, gsel, loads = _dispatch_block(
        cand, gate_ref[...], nc, loads_ref[...],
        n_experts=n_experts, w_mode=w_mode,
    )
    idx_ref[...] = idx
    gsel_ref[...] = gsel
    loads_ref[...] = loads


@functools.partial(
    jax.jit,
    static_argnames=("n_experts", "d_base", "d_max", "block", "interpret",
                     "w_mode"),
)
def moe_adaptive_dispatch(
    cand: jnp.ndarray,
    cgate: jnp.ndarray,
    tbl_keys: jnp.ndarray,
    tbl_ncand: jnp.ndarray,
    n_experts: int,
    d_base: int = 2,
    d_max: int = 4,
    block: int = 256,
    interpret: Optional[bool] = None,
    w_mode: bool = False,
):
    """Adaptive MoE dispatch: cand/cgate (T, k, d_max) router-ranked expert
    candidates per slot, tbl_keys/tbl_ncand (T/block, H) per-block
    expert-popularity head tables (models.moe.expert_head_tables with the
    same `block`).  Tokens whose preferred expert misses the table (or hits
    as tail) use d_base candidate lanes — exact PKG-PoTC; head hits open
    d(e) <= d_max lanes, and W_SENTINEL entries (any_worker tables) route the
    token's slots through the global water-fill — pass w_mode=True with such
    tables.  Returns (idx (T,k), gates (T,k), loads (E,)).

    T must divide by block.  interpret=None resolves via kernels.platform.
    """
    T, k, _ = cand.shape
    H = tbl_keys.shape[1]
    assert T % block == 0, (T, block)
    assert tbl_keys.shape == (T // block, H) == tbl_ncand.shape
    grid = (T // block,)
    kern = functools.partial(
        _kernel_adaptive, n_experts=n_experts, d_base=d_base, d_max=d_max,
        w_mode=w_mode,
    )
    idx, gsel, loads = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, k, cand.shape[2]), lambda i: (i, 0, 0)),
            pl.BlockSpec((block, k, cand.shape[2]), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, H), lambda i: (i, 0)),
            pl.BlockSpec((1, H), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block, k), lambda i: (i, 0)),
            pl.BlockSpec((block, k), lambda i: (i, 0)),
            pl.BlockSpec((1, n_experts), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, k), jnp.int32),
            jax.ShapeDtypeStruct((T, k), cgate.dtype),
            jax.ShapeDtypeStruct((1, n_experts), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(
        cand.astype(jnp.int32),
        cgate,
        tbl_keys.astype(jnp.int32),
        tbl_ncand.astype(jnp.int32),
    )
    return idx, gsel, loads[0]
