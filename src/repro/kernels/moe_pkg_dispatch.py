"""Pallas TPU kernel: fused PKG-PoTC expert choice for MoE dispatch.

Grid: one program per block of T_blk tokens; TPU grid steps run sequentially
on a core, so the (1, E) fp32 expert-load vector persists in VMEM scratch
across blocks — a single running local estimator, exactly the semantics of
models.moe._pkg_choose (intra-block-stale loads, paper §3.2).

Per block, for each of the k slots every token has 2 candidate experts (its
next-two router-ranked experts): candidate loads are fetched with a one-hot
matmul, the lane-wise argmin picks the less-loaded candidate, and the block
histogram updates the load vector — no gathers or scatters.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(cand_ref, gate_ref, idx_ref, gsel_ref, loads_ref, *, n_experts):
    blk, k, _ = cand_ref.shape
    eid = jnp.arange(n_experts, dtype=jnp.int32)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        loads_ref[...] = jnp.zeros_like(loads_ref)

    loads = loads_ref[0]  # (E,) f32
    cand = cand_ref[...]  # (blk, k, 2)
    gate = gate_ref[...]
    onehot_c = (cand[..., None] == eid).astype(jnp.float32)  # (blk,k,2,E)
    lc = jax.lax.dot_general(
        onehot_c.reshape(blk * k * 2, n_experts),
        loads.reshape(n_experts, 1),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(blk, k, 2)
    sel = jnp.argmin(lc, axis=-1)  # ties -> first (higher-gate) candidate
    idx = jnp.take_along_axis(cand, sel[..., None], axis=-1)[..., 0]
    gsel = jnp.take_along_axis(gate, sel[..., None], axis=-1)[..., 0]
    idx_ref[...] = idx
    gsel_ref[...] = gsel
    hist = (idx.reshape(-1)[:, None] == eid).astype(jnp.float32).sum(axis=0)
    loads_ref[0] = loads + hist


@functools.partial(jax.jit, static_argnames=("n_experts", "block", "interpret"))
def moe_pkg_dispatch(
    cand: jnp.ndarray,
    cgate: jnp.ndarray,
    n_experts: int,
    block: int = 256,
    interpret: bool = True,
):
    """cand (T,k,2) int32, cgate (T,k,2) f32 -> (idx (T,k), gates (T,k), loads (E,)).

    T must divide by block.
    """
    T, k, _ = cand.shape
    assert T % block == 0, (T, block)
    grid = (T // block,)
    kern = functools.partial(_kernel, n_experts=n_experts)
    idx, gsel, loads = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, k, 2), lambda i: (i, 0, 0)),
            pl.BlockSpec((block, k, 2), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block, k), lambda i: (i, 0)),
            pl.BlockSpec((block, k), lambda i: (i, 0)),
            pl.BlockSpec((1, n_experts), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, k), jnp.int32),
            jax.ShapeDtypeStruct((T, k), cgate.dtype),
            jax.ShapeDtypeStruct((1, n_experts), jnp.float32),
        ],
        interpret=interpret,
    )(cand.astype(jnp.int32), cgate)
    return idx, gsel, loads[0]
