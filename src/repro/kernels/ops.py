"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container) kernels execute in interpret mode; on TPU the same
calls compile natively.  `use_kernels()` is the production switch consulted
by higher layers.
"""
from __future__ import annotations

import jax

from repro.kernels.adaptive_route import (
    adaptive_route,
    adaptive_route_online,
    w_route,
)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_pkg_dispatch import moe_pkg_dispatch
from repro.kernels.pkg_route import pkg_route
from repro.kernels.rmsnorm import rmsnorm

__all__ = [
    "adaptive_route",
    "adaptive_route_online",
    "w_route",
    "flash_attention",
    "moe_pkg_dispatch",
    "pkg_route",
    "rmsnorm",
    "interpret_mode",
]


def interpret_mode() -> bool:
    """True when Pallas must run in interpret mode (non-TPU backends)."""
    return jax.default_backend() != "tpu"
