"""Public jit'd wrappers for the Pallas kernels.

Every entry point defaults interpret=None, resolved per-call by
kernels.platform (compile natively on TPU, interpret elsewhere) — callers no
longer need to thread the flag.  `use_kernels()` / `interpret_mode()` are the
production switches consulted by higher layers.
"""
from __future__ import annotations

from repro.kernels.adaptive_route import (
    adaptive_route,
    adaptive_route_online,
    w_route,
)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_pkg_dispatch import moe_adaptive_dispatch, moe_pkg_dispatch
from repro.kernels.pkg_route import pkg_route
from repro.kernels.platform import interpret_default as interpret_mode
from repro.kernels.rmsnorm import rmsnorm

__all__ = [
    "adaptive_route",
    "adaptive_route_online",
    "w_route",
    "flash_attention",
    "moe_adaptive_dispatch",
    "moe_pkg_dispatch",
    "pkg_route",
    "rmsnorm",
    "interpret_mode",
]
