"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

Each `ref_*` mirrors the kernel contract exactly, including tie-breaking
(argmin -> first candidate) and block-staleness semantics of the PKG routers.
The routing oracles are all built on kernels/route_core.oracle_block_step —
the gather-based host twin of the kernels' route_block — so the mask /
W-sentinel / water-fill / tie-break semantics are SHARED with the kernels
(one implementation, it cannot drift) while the load fetch and the W pick
deliberately use plain indexing instead of the kernels' one-hot matmuls:
the differential tests check the MXU formulation against straightforward
gathers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.estimation import W_SENTINEL
from repro.core.hashing import hash_choices
from repro.kernels.route_core import head_table_ncand, oracle_block_step


def _ref_inv_cap(capacities, n_workers):
    """(n_workers,) f32 reciprocal-capacity row, or None — the SAME
    1/f32(cap) the kernel wrappers form, so oracle and kernel normalize by
    bit-identical factors."""
    if capacities is None:
        return None
    return 1.0 / jnp.asarray(capacities, jnp.float32).reshape(n_workers)


def ref_pkg_route(keys, n_workers: int, d: int = 2, seed: int = 0,
                  chunk: int = 1024, block: int = 128, capacities=None):
    """Chunked batch-greedy PKG (matches kernels/pkg_route.py).

    Chunks are independent local estimators; within a chunk, loads update
    every `block` keys.  Returns (assign (N,), loads (N//chunk, n_workers)).
    """
    N = keys.shape[0]
    assert N % chunk == 0 and chunk % block == 0
    icap = _ref_inv_cap(capacities, n_workers)
    cand = hash_choices(keys, n_workers, d=d, seed=seed)  # (N, d)
    cand = cand.reshape(N // chunk, chunk // block, block, d)

    def chunk_fn(cand_c):
        def step(loads, cb):  # cb (block, d)
            loads, choice, _, _ = oracle_block_step(
                loads, cb, None, n_entities=n_workers, w_mode=False,
                inv_cap=icap,
            )
            return loads, choice

        loads0 = jnp.zeros((n_workers,), jnp.float32)
        loads, choices = lax.scan(step, loads0, cand_c)
        return choices.reshape(-1), loads

    assign, loads = jax.vmap(chunk_fn)(cand)
    return assign.reshape(-1).astype(jnp.int32), loads


def ref_adaptive_route(keys, n_cand, n_workers: int, d_max: int = 4,
                       seed: int = 0, chunk: int = 1024, block: int = 128,
                       w_mode: bool = False, capacities=None):
    """Chunked batch-greedy with per-key candidate counts
    (matches kernels/adaptive_route.py, including the route_core MASK
    sentinel and, with w_mode=True, the W_SENTINEL water-fill path).

    Returns (assign (N,), loads (N//chunk, n_workers))."""
    N = keys.shape[0]
    assert N % chunk == 0 and chunk % block == 0
    icap = _ref_inv_cap(capacities, n_workers)
    cand = hash_choices(keys, n_workers, d=d_max, seed=seed)  # (N, d_max)
    cand = cand.reshape(N // chunk, chunk // block, block, d_max)
    nc = n_cand.astype(jnp.int32).reshape(N // chunk, chunk // block, block)

    def chunk_fn(cand_c, nc_c):
        def step(loads, inp):  # cb (block, d_max), ncb (block,)
            cb, ncb = inp
            loads, choice, _, _ = oracle_block_step(
                loads, cb, ncb, n_entities=n_workers, w_mode=w_mode,
                inv_cap=icap,
            )
            return loads, choice

        loads0 = jnp.zeros((n_workers,), jnp.float32)
        loads, choices = lax.scan(step, loads0, (cand_c, nc_c))
        return choices.reshape(-1), loads

    assign, loads = jax.vmap(chunk_fn)(cand, nc)
    return assign.reshape(-1).astype(jnp.int32), loads


def ref_adaptive_route_online(keys, tbl_keys, tbl_ncand, n_workers: int,
                              d_base: int = 2, d_max: int = 8, seed: int = 0,
                              chunk: int = 1024, block: int = 128,
                              w_mode: bool = False, capacities=None):
    """Chunked batch-greedy against per-block head tables
    (matches kernels/adaptive_route.py::adaptive_route_online; the table
    lookup is literally the kernels' head_table_ncand and the greedy core
    is the shared oracle_block_step).

    Returns (assign (N,), loads (N//chunk, n_workers))."""
    N = keys.shape[0]
    H = tbl_keys.shape[1]
    assert N % chunk == 0 and chunk % block == 0
    icap = _ref_inv_cap(capacities, n_workers)
    cand = hash_choices(keys, n_workers, d=d_max, seed=seed)  # (N, d_max)
    cand = cand.reshape(N // chunk, chunk // block, block, d_max)
    kb = keys.astype(jnp.int32).reshape(N // chunk, chunk // block, block)
    tk = tbl_keys.astype(jnp.int32).reshape(N // chunk, chunk // block, H)
    tn = tbl_ncand.astype(jnp.int32).reshape(N // chunk, chunk // block, H)

    def chunk_fn(cand_c, kb_c, tk_c, tn_c):
        def step(loads, inp):
            cb, kbb, tkb, tnb = inp  # (block,d_max) (block,) (H,) (H,)
            nc = head_table_ncand(kbb, tkb, tnb, d_base, d_max)
            loads, choice, _, _ = oracle_block_step(
                loads, cb, nc, n_entities=n_workers, w_mode=w_mode,
                inv_cap=icap,
            )
            return loads, choice

        loads0 = jnp.zeros((n_workers,), jnp.float32)
        loads, choices = lax.scan(step, loads0, (cand_c, kb_c, tk_c, tn_c))
        return choices.reshape(-1), loads

    assign, loads = jax.vmap(chunk_fn)(cand, kb, tk, tn)
    return assign.reshape(-1).astype(jnp.int32), loads


def ref_w_route(keys, is_head, n_workers: int, d: int = 2, seed: int = 0,
                chunk: int = 1024, block: int = 128, capacities=None):
    """Oracle for kernels/adaptive_route.py::w_route: head-flagged keys take
    the global argmin (W-Choices), tail keys PKG's d-candidate step.

    Returns (assign (N,), loads (N//chunk, n_workers))."""
    flags = jnp.asarray(is_head).astype(jnp.int32)
    n_cand = jnp.where(flags != 0, jnp.int32(W_SENTINEL), jnp.int32(d))
    return ref_adaptive_route(
        keys, n_cand, n_workers, d_max=d, seed=seed, chunk=chunk, block=block,
        w_mode=True, capacities=capacities,
    )


def ref_w_route_online(keys, tbl_keys, tbl_ncand, n_workers: int,
                       d_base: int = 2, d_max: int = 8, seed: int = 0,
                       chunk: int = 1024, block: int = 128, capacities=None):
    """Oracle for the online W-Choices path: per-block head tables emitted by
    estimation.online_head_tables(any_worker=True), whose W_SENTINEL entries
    route through the global argmin.  Identical code to
    ref_adaptive_route_online with w_mode=True — the sentinel handling lives
    in the shared oracle_block_step/head_table_ncand pair — named
    separately so callers state which contract they exercise."""
    return ref_adaptive_route_online(
        keys, tbl_keys, tbl_ncand, n_workers, d_base=d_base, d_max=d_max,
        seed=seed, chunk=chunk, block=block, w_mode=True, capacities=capacities,
    )


def _ref_dispatch_block(loads, cand, gate, nc, *, n_experts, w_mode):
    """One MoE token block through the shared oracle core: flatten the k
    slots into blk*k lanes, route, gather the winning gate (spilled W lanes
    keep their slot's top-ranked gate — the kernel's contract)."""
    blk, k, C = cand.shape
    cand_f = cand.reshape(blk * k, C)
    gate_f = gate.reshape(blk * k, C)
    loads, choice, sel, is_w = oracle_block_step(
        loads, cand_f, nc, n_entities=n_experts, w_mode=w_mode
    )
    gsel = jnp.take_along_axis(gate_f, sel[:, None], axis=-1)[:, 0]
    if w_mode:
        gsel = jnp.where(is_w, gate_f[:, 0], gsel)
    return loads, choice.reshape(blk, k), gsel.reshape(blk, k)


def ref_moe_pkg_dispatch(cand, cgate, n_experts: int, block: int = 256):
    """Sequential block-greedy PoTC over expert candidate pairs.

    cand (T,k,2) int32, cgate (T,k,2) f32 -> (idx (T,k), gates (T,k),
    loads (n_experts,)).  Loads persist across blocks (single estimator).
    """
    T, k, _ = cand.shape
    assert T % block == 0
    cand_b = cand.reshape(T // block, block, k, 2)
    gate_b = cgate.reshape(T // block, block, k, 2)

    def step(loads, inp):
        c, g = inp
        loads, idx, gsel = _ref_dispatch_block(
            loads, c, g, None, n_experts=n_experts, w_mode=False
        )
        return loads, (idx, gsel)

    loads0 = jnp.zeros((n_experts,), jnp.float32)
    loads, (idx, gates) = lax.scan(step, loads0, (cand_b, gate_b))
    return idx.reshape(T, k), gates.reshape(T, k), loads


def ref_moe_adaptive_dispatch(cand, cgate, tbl_keys, tbl_ncand,
                              n_experts: int, d_base: int = 2, d_max: int = 4,
                              block: int = 256, w_mode: bool = False):
    """Oracle for kernels/moe_pkg_dispatch.py::moe_adaptive_dispatch — and
    THE host routing path of models.moe's d_choices/w_choices router modes
    (models.moe._adaptive_choose wraps this, so layer, kernel, and oracle
    share one choose implementation; it is differentiable w.r.t. cgate).

    cand/cgate (T, k, d_max), tbl_keys/tbl_ncand (T/block, H) expert-
    popularity head tables.  Returns (idx (T,k), gates (T,k), loads (E,)).
    """
    T, k, C = cand.shape
    H = tbl_keys.shape[1]
    assert T % block == 0, (T, block)
    assert tbl_keys.shape == (T // block, H) == tbl_ncand.shape
    cand_b = cand.astype(jnp.int32).reshape(T // block, block, k, C)
    gate_b = cgate.reshape(T // block, block, k, C)
    tk = tbl_keys.astype(jnp.int32)
    tn = tbl_ncand.astype(jnp.int32)

    def step(loads, inp):
        c, g, tkb, tnb = inp  # (block,k,C) (block,k,C) (H,) (H,)
        pref = c[:, 0, 0]  # token's preferred (top-ranked) expert
        nc_tok = head_table_ncand(pref, tkb, tnb, d_base, d_max)
        nc = jnp.broadcast_to(nc_tok[:, None], (block, k)).reshape(block * k)
        loads, idx, gsel = _ref_dispatch_block(
            loads, c, g, nc, n_experts=n_experts, w_mode=w_mode
        )
        return loads, (idx, gsel)

    loads0 = jnp.zeros((n_experts,), jnp.float32)
    loads, (idx, gates) = lax.scan(step, loads0, (cand_b, gate_b, tk, tn))
    return idx.reshape(T, k), gates.reshape(T, k), loads


def ref_flash_attention(q, k, v, causal: bool = True, window: int = 0):
    """Exact softmax attention with GQA + causal + sliding-window masks.

    q (B,S,H,hd), k/v (B,T,Kv,hd) -> (B,S,H,hd).  fp32 softmax.
    """
    B, S, H, hd = q.shape
    T, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    qg = q.reshape(B, S, Kv, G, hd)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    logits = logits * (hd ** -0.5)
    q_pos = jnp.arange(S)[:, None] + (T - S)  # assume k covers [0, T)
    k_pos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > (q_pos - window)
    logits = jnp.where(mask[None, None, None], logits, -1e9)
    p = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", p, v)
    return out.reshape(B, S, H, hd)


def ref_rmsnorm(x, w, eps: float = 1e-6):
    """(..., D) RMS norm with (1 + w) scale, fp32 accumulation."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))).astype(x.dtype)
