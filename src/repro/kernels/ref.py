"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

Each `ref_*` mirrors the kernel contract exactly, including tie-breaking
(argmin -> first candidate) and block-staleness semantics of the PKG routers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.estimation import W_SENTINEL
from repro.core.hashing import hash_choices


def ref_pkg_route(keys, n_workers: int, d: int = 2, seed: int = 0,
                  chunk: int = 1024, block: int = 128):
    """Chunked batch-greedy PKG (matches kernels/pkg_route.py).

    Chunks are independent local estimators; within a chunk, loads update
    every `block` keys.  Returns (assign (N,), loads (N//chunk, n_workers)).
    """
    N = keys.shape[0]
    assert N % chunk == 0 and chunk % block == 0
    cand = hash_choices(keys, n_workers, d=d, seed=seed)  # (N, d)
    cand = cand.reshape(N // chunk, chunk // block, block, d)

    def chunk_fn(cand_c):
        def step(loads, cb):  # cb (block, d)
            lc = loads[cb]  # (block, d)
            sel = jnp.argmin(lc, axis=-1)
            choice = jnp.take_along_axis(cb, sel[:, None], axis=-1)[:, 0]
            hist = jax.nn.one_hot(choice, n_workers, dtype=jnp.float32).sum(0)
            return loads + hist, choice

        loads0 = jnp.zeros((n_workers,), jnp.float32)
        loads, choices = lax.scan(step, loads0, cand_c)
        return choices.reshape(-1), loads

    assign, loads = jax.vmap(chunk_fn)(cand)
    return assign.reshape(-1).astype(jnp.int32), loads


def _masked_block_step(loads, cb, ncb, n_workers: int, d_max: int,
                       w_mode: bool = False):
    """One vector block of the masked batch-greedy: the shared oracle core
    for both adaptive routers (1e30 sentinel, first-index tie-break).

    With w_mode, lanes with ncb == W_SENTINEL take the W-Choices path: the
    r-th such lane gets the r-th sequential global-argmin (water-fill) of the
    block-start loads row.  The picks come from the kernel's own
    adaptive_route._waterfill_picks, so oracle and kernel share one
    implementation of the reduction's sentinel/tie-break contract;
    w_mode=False skips it for sentinel-free candidate counts, exactly
    mirroring the kernel's static flag."""
    from repro.kernels.adaptive_route import _waterfill_picks

    block = cb.shape[0]
    col = jnp.arange(d_max, dtype=jnp.int32)
    lc = loads[cb]  # (block, d_max)
    is_w = ncb == jnp.int32(W_SENTINEL)
    nc_tail = jnp.where(is_w, d_max, ncb) if w_mode else ncb
    lc = jnp.where(col[None, :] < nc_tail[:, None], lc, jnp.float32(1e30))
    sel = jnp.argmin(lc, axis=-1)
    choice = jnp.take_along_axis(cb, sel[:, None], axis=-1)[:, 0]
    if w_mode:
        rank = jnp.cumsum(is_w.astype(jnp.int32)) - is_w
        picks = _waterfill_picks(
            loads[None, :], n_workers=n_workers, block=block
        )
        choice = jnp.where(is_w, picks[rank], choice)
    hist = jax.nn.one_hot(choice, n_workers, dtype=jnp.float32).sum(0)
    return loads + hist, choice


def ref_adaptive_route(keys, n_cand, n_workers: int, d_max: int = 4,
                       seed: int = 0, chunk: int = 1024, block: int = 128,
                       w_mode: bool = False):
    """Chunked batch-greedy with per-key candidate counts
    (matches kernels/adaptive_route.py, including the 1e30 mask sentinel and,
    with w_mode=True, the W_SENTINEL water-fill path).

    Returns (assign (N,), loads (N//chunk, n_workers))."""
    N = keys.shape[0]
    assert N % chunk == 0 and chunk % block == 0
    cand = hash_choices(keys, n_workers, d=d_max, seed=seed)  # (N, d_max)
    cand = cand.reshape(N // chunk, chunk // block, block, d_max)
    nc = n_cand.astype(jnp.int32).reshape(N // chunk, chunk // block, block)

    def chunk_fn(cand_c, nc_c):
        def step(loads, inp):  # cb (block, d_max), ncb (block,)
            cb, ncb = inp
            return _masked_block_step(loads, cb, ncb, n_workers, d_max, w_mode)

        loads0 = jnp.zeros((n_workers,), jnp.float32)
        loads, choices = lax.scan(step, loads0, (cand_c, nc_c))
        return choices.reshape(-1), loads

    assign, loads = jax.vmap(chunk_fn)(cand, nc)
    return assign.reshape(-1).astype(jnp.int32), loads


def ref_adaptive_route_online(keys, tbl_keys, tbl_ncand, n_workers: int,
                              d_base: int = 2, d_max: int = 8, seed: int = 0,
                              chunk: int = 1024, block: int = 128,
                              w_mode: bool = False):
    """Chunked batch-greedy against per-block head tables
    (matches kernels/adaptive_route.py::adaptive_route_online; the table
    lookup is literally the kernel's _head_table_ncand and the greedy core
    is the shared _masked_block_step).

    Returns (assign (N,), loads (N//chunk, n_workers))."""
    from repro.kernels.adaptive_route import _head_table_ncand

    N = keys.shape[0]
    H = tbl_keys.shape[1]
    assert N % chunk == 0 and chunk % block == 0
    cand = hash_choices(keys, n_workers, d=d_max, seed=seed)  # (N, d_max)
    cand = cand.reshape(N // chunk, chunk // block, block, d_max)
    kb = keys.astype(jnp.int32).reshape(N // chunk, chunk // block, block)
    tk = tbl_keys.astype(jnp.int32).reshape(N // chunk, chunk // block, H)
    tn = tbl_ncand.astype(jnp.int32).reshape(N // chunk, chunk // block, H)

    def chunk_fn(cand_c, kb_c, tk_c, tn_c):
        def step(loads, inp):
            cb, kbb, tkb, tnb = inp  # (block,d_max) (block,) (H,) (H,)
            nc = _head_table_ncand(kbb, tkb, tnb, d_base, d_max)
            return _masked_block_step(loads, cb, nc, n_workers, d_max, w_mode)

        loads0 = jnp.zeros((n_workers,), jnp.float32)
        loads, choices = lax.scan(step, loads0, (cand_c, kb_c, tk_c, tn_c))
        return choices.reshape(-1), loads

    assign, loads = jax.vmap(chunk_fn)(cand, kb, tk, tn)
    return assign.reshape(-1).astype(jnp.int32), loads


def ref_w_route(keys, is_head, n_workers: int, d: int = 2, seed: int = 0,
                chunk: int = 1024, block: int = 128):
    """Oracle for kernels/adaptive_route.py::w_route: head-flagged keys take
    the global argmin (W-Choices), tail keys PKG's d-candidate step.

    Returns (assign (N,), loads (N//chunk, n_workers))."""
    flags = jnp.asarray(is_head).astype(jnp.int32)
    n_cand = jnp.where(flags != 0, jnp.int32(W_SENTINEL), jnp.int32(d))
    return ref_adaptive_route(
        keys, n_cand, n_workers, d_max=d, seed=seed, chunk=chunk, block=block,
        w_mode=True,
    )


def ref_w_route_online(keys, tbl_keys, tbl_ncand, n_workers: int,
                       d_base: int = 2, d_max: int = 8, seed: int = 0,
                       chunk: int = 1024, block: int = 128):
    """Oracle for the online W-Choices path: per-block head tables emitted by
    estimation.online_head_tables(any_worker=True), whose W_SENTINEL entries
    route through the global argmin.  Identical code to
    ref_adaptive_route_online with w_mode=True — the sentinel handling lives
    in the shared _masked_block_step/_head_table_ncand pair — named
    separately so callers state which contract they exercise."""
    return ref_adaptive_route_online(
        keys, tbl_keys, tbl_ncand, n_workers, d_base=d_base, d_max=d_max,
        seed=seed, chunk=chunk, block=block, w_mode=True,
    )


def ref_moe_pkg_dispatch(cand, cgate, n_experts: int, block: int = 256):
    """Sequential block-greedy PoTC over expert candidate pairs.

    cand (T,k,2) int32, cgate (T,k,2) f32 -> (idx (T,k), gates (T,k),
    loads (n_experts,)).  Loads persist across blocks (single estimator).
    """
    T, k, _ = cand.shape
    assert T % block == 0
    cand_b = cand.reshape(T // block, block, k, 2)
    gate_b = cgate.reshape(T // block, block, k, 2)

    def step(loads, inp):
        c, g = inp
        lc = loads[c]  # (block,k,2)
        sel = jnp.argmin(lc, axis=-1)
        idx = jnp.take_along_axis(c, sel[..., None], axis=-1)[..., 0]
        gsel = jnp.take_along_axis(g, sel[..., None], axis=-1)[..., 0]
        hist = jax.nn.one_hot(idx.reshape(-1), n_experts, dtype=jnp.float32).sum(0)
        return loads + hist, (idx, gsel)

    loads0 = jnp.zeros((n_experts,), jnp.float32)
    loads, (idx, gates) = lax.scan(step, loads0, (cand_b, gate_b))
    return idx.reshape(T, k), gates.reshape(T, k), loads


def ref_flash_attention(q, k, v, causal: bool = True, window: int = 0):
    """Exact softmax attention with GQA + causal + sliding-window masks.

    q (B,S,H,hd), k/v (B,T,Kv,hd) -> (B,S,H,hd).  fp32 softmax.
    """
    B, S, H, hd = q.shape
    T, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    qg = q.reshape(B, S, Kv, G, hd)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    logits = logits * (hd ** -0.5)
    q_pos = jnp.arange(S)[:, None] + (T - S)  # assume k covers [0, T)
    k_pos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > (q_pos - window)
    logits = jnp.where(mask[None, None, None], logits, -1e9)
    p = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", p, v)
    return out.reshape(B, S, H, hd)


def ref_rmsnorm(x, w, eps: float = 1e-6):
    """(..., D) RMS norm with (1 + w) scale, fp32 accumulation."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))).astype(x.dtype)
