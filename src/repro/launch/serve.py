"""Serving launcher: batched generation + PoTC replica routing demo.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --tiny \
      --batch 4 --prompt-len 16 --new-tokens 32 --replicas 4
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--requests", type=int, default=2000)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config, make_tiny
    from repro.core.streams import zipf_stream
    from repro.models import init_params
    from repro.serving import KGScheduler, PoTCScheduler, ServeEngine

    cfg = make_tiny(get_config(args.arch)) if args.tiny else get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    engine = ServeEngine(cfg, params, max_len=args.prompt_len + args.new_tokens)

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(1, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )
    out = engine.generate(prompts, n_new=args.new_tokens)
    print(f"generated batch {out.shape}; sample row: {np.asarray(out[0])[:24]}...")

    # replica routing: skewed session keys, PoTC vs sticky hashing
    keys = zipf_stream(args.requests, max(args.requests // 20, 50), 1.1, seed=args.seed)
    potc, kg = PoTCScheduler(args.replicas), KGScheduler(args.replicas)
    for k in keys:
        potc.route(int(k))
        kg.route(int(k))
    for name, s in (("PoTC", potc), ("KG", kg)):
        loads = s.loads
        print(
            f"{name}: replica loads {loads.astype(int).tolist()} "
            f"imbalance={(loads.max()-loads.mean())/loads.sum():.4f}"
        )


if __name__ == "__main__":
    main()
