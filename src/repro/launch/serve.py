"""Serving launcher: batched generation + closed-loop replica routing demo.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --tiny \
      --batch 4 --prompt-len 16 --new-tokens 32 --replicas 50 \
      --scheduler w_choices

The routing demo drives the discrete-event simulator (serving.sim), so
schedulers receive completion events and their ledgers track OUTSTANDING
work — the number printed as "outstanding imbalance" is a true queue-depth
imbalance, not a cumulative total.  Cumulative routed-work balance and the
prefix-cache hit-rate are reported alongside, plus per-tenant SLO violations
over a skewed multi-tenant session stream.

Operational knobs mirror the simulator's failure/overload/elastic surfaces
(docs/operator-guide.md): --queue-bound bounds each replica's FIFO and
sheds overflow, --kill-at fails a replica mid-stream (its queue drains and
requeues over the live mask), --capacities gives replicas heterogeneous
speeds (a pattern like "1,2,4" tiles across the pool; routing normalizes
loads by capacity and the simulator serves at the true rates), and
--autoscale MIN:MAX runs serving.sim.Autoscaler so the live pool tracks the
offered load.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.routing import DEFAULT_SCHEDULER, scheduler_sweep_names

SCHEDULERS = scheduler_sweep_names()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--replicas", type=int, default=50)
    ap.add_argument("--requests", type=int, default=4000)
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--scheduler", default=DEFAULT_SCHEDULER, choices=SCHEDULERS,
                    help="routing policy for the detailed run (others are "
                         "printed side by side for comparison)")
    ap.add_argument("--utilization", type=float, default=0.7,
                    help="offered load as a fraction of aggregate capacity; "
                         ">= 1 needs --queue-bound (shedding) to stay bounded")
    ap.add_argument("--cache-capacity", type=int, default=64)
    ap.add_argument("--slo", type=float, default=0.1)
    ap.add_argument("--queue-bound", type=int, default=None,
                    help="bounded per-replica FIFO: overflow arrivals are "
                         "shed (queue-based load leveling)")
    ap.add_argument("--kill-at", type=float, default=None, metavar="FRAC",
                    help="kill one replica after this fraction of the stream "
                         "(0-1): its queue drains and redistributes via the "
                         "live-replica mask")
    ap.add_argument("--capacities", default=None, metavar="C1,C2,...",
                    help="per-replica speed pattern, tiled across the pool "
                         "(e.g. '1,2,4'): load comparisons become capacity-"
                         "normalized and replicas serve at their true rates")
    ap.add_argument("--autoscale", default=None, metavar="MIN:MAX",
                    help="elastic replica pool (serving.sim.Autoscaler): "
                         "start at MIN live replicas, grow to at most MAX "
                         "under load, shrink back in the lulls")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config, make_tiny
    from repro.core.routing import make_policy
    from repro.core.streams import multi_tenant_stream
    from repro.models import init_params
    from repro.serving import (
        Autoscaler,
        PolicyScheduler,
        ServeEngine,
        simulate_serving,
    )

    capacities = None
    if args.capacities is not None:
        pat = np.asarray([float(c) for c in args.capacities.split(",")])
        capacities = np.resize(pat, args.replicas)
    autoscaler = None
    if args.autoscale is not None:
        lo, hi = (int(v) for v in args.autoscale.split(":"))
        autoscaler = Autoscaler(
            min_replicas=lo, max_replicas=hi, initial=lo,
            check_every=max(args.requests // 100, 1),
            cooldown=max(args.requests // 40, 1),
        )

    cfg = make_tiny(get_config(args.arch)) if args.tiny else get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    engine = ServeEngine(cfg, params, max_len=args.prompt_len + args.new_tokens)

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(1, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )
    out = engine.generate(prompts, n_new=args.new_tokens)
    print(f"generated batch {out.shape}; sample row: {np.asarray(out[0])[:24]}...")

    # closed-loop replica routing: skewed multi-tenant session keys, with
    # completions driven by the simulator (loads = outstanding work).
    keys, tenants = multi_tenant_stream(
        args.requests, n_tenants=args.tenants,
        n_keys=max(args.requests // 40, 50), z=1.6,
        weights=np.arange(args.tenants, 0, -1), seed=args.seed,
    )
    kill_schedule = None
    if args.kill_at is not None:
        # kill replica 0 after --kill-at of the stream's arrival window
        dt = 1.0 / (args.utilization * args.replicas)
        kill_schedule = [(args.kill_at * args.requests * dt, 0)]
    print(
        f"\nrouting {args.requests} requests, {args.replicas} replicas, "
        f"{args.tenants} tenants, util={args.utilization:.0%}, "
        f"prefix-cache {args.cache_capacity}/replica, SLO {args.slo}"
        + (f", queue-bound {args.queue_bound}" if args.queue_bound else "")
        + (f", kill replica 0 @ {args.kill_at:.0%}" if kill_schedule else "")
        + (f", capacities {args.capacities} tiled" if capacities is not None
           else "")
        + (f", autoscale {args.autoscale}" if autoscaler else "")
        + ":"
    )
    order = [args.scheduler] + [s for s in SCHEDULERS if s != args.scheduler]
    for name in order:
        sched = PolicyScheduler(
            make_policy(name, args.replicas, d=2, seed=args.seed),
            capacities=capacities,
        )
        res = simulate_serving(
            sched, keys, tenants=tenants, utilization=args.utilization,
            cache_capacity=args.cache_capacity, slo=args.slo,
            queue_bound=args.queue_bound, kill_schedule=kill_schedule,
            autoscaler=autoscaler,
        )
        star = "*" if name == args.scheduler else " "
        print(
            f" {star}{name:10s} cache-hit={res.hit_rate:.3f}  "
            f"outstanding-imbalance={res.outstanding_imbalance:.4f}  "
            f"routed-work-imbalance={res.assign_imbalance:.4f}  "
            f"p50/p99 latency={res.latency_p50:.2f}/{res.latency_p99:.2f}  "
            f"shed={res.shed}  requeued={res.requeued}  "
            f"SLO-violating-tenants={res.tenant_report['tenants_violating']}"
            f"/{args.tenants}  session-fanout<= {res.session_fanout_max}"
            + (f"  scale-events={len(res.scale_events)}" if autoscaler else "")
        )
        assert res.completed + res.shed == args.requests, "lost completions"
        assert sched.loads.sum() == 0.0, "drain left outstanding work"
    print(
        "\n(*) = --scheduler selection.  W-Choices keeps cold sessions on "
        "<= 2 replicas (warm\nprefix caches) while hot sessions spread for "
        "balance — the paper's key splitting\nat the serving edge."
    )


if __name__ == "__main__":
    main()
