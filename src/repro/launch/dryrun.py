"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: the SPMD
partitioner must accept every sharding, the compiled per-device program's
memory_analysis must fit a v5e (16 GB), and cost/collective analysis feeds
EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b \
      --shapes train_4k,prefill_32k --mesh both --out experiments/dryrun
"""
# The host platform must present 512 placeholder devices BEFORE jax (or
# anything importing jax) initializes — these two lines must stay first.
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import ALL_ARCHS, SHAPES, TrainConfig, get_config, shapes_for  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import input_specs  # noqa: E402
from repro.models.transformer import decode_step, init_defs, prefill_logits  # noqa: E402
from repro.optim.adamw import adamw_init  # noqa: E402
from repro.parallel.sharding import (  # noqa: E402
    batch_specs,
    cache_specs,
    make_plan,
    make_sharder,
    param_shardings,
)
from repro.parallel.spec import abstract  # noqa: E402
from repro.roofline.analysis import HW, collective_bytes, model_flops, roofline_report  # noqa: E402
from repro.train.loop import make_train_step  # noqa: E402

V5E_HBM = 16 * 1024**3

# per-(arch, shape) gradient-accumulation overrides (memory fit, §Perf log)
MICROBATCHES: dict[tuple[str, str], int] = {}


def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def build_cell(cfg, shape, mesh, force_big=None):
    """Returns (jitted_fn, abstract_args) for one dry-run cell."""
    plan = make_plan(
        cfg, mesh, force_big=force_big, inference=shape.kind != "train"
    )
    sh = make_sharder(cfg, mesh, plan, shape.kind, shape.global_batch)
    pspecs = param_shardings(cfg, mesh, plan)
    specs = input_specs(cfg, shape)
    bspecs = batch_specs(cfg, plan, shape.kind, shape.global_batch)
    rep = NamedSharding(mesh, P())

    if shape.kind == "train":
        params_abs = abstract(init_defs(cfg), jnp.float32)
        opt_abs = jax.eval_shape(adamw_init, params_abs)
        p_sh = _named(mesh, pspecs)
        o_sh = {"m": p_sh, "v": p_sh, "count": rep}
        mb = MICROBATCHES.get((cfg.name, shape.name), 1)
        tcfg = TrainConfig(remat=True, microbatches=mb)
        step = make_train_step(cfg, tcfg, sh=sh, grad_shardings=p_sh)
        fn = jax.jit(
            step,
            in_shardings=(p_sh, o_sh, _named(mesh, bspecs), rep),
            out_shardings=(p_sh, o_sh, rep),
            donate_argnums=(0, 1),
        )
        args = (params_abs, opt_abs, specs["batch"], jax.ShapeDtypeStruct((), jnp.int32))
        return fn, args

    params_abs = abstract(init_defs(cfg), jnp.bfloat16)
    p_sh = _named(mesh, pspecs)
    if shape.kind == "prefill":
        fn = jax.jit(
            lambda p, b: prefill_logits(p, b, cfg, sh=sh),
            in_shardings=(p_sh, _named(mesh, bspecs)),
            out_shardings=rep,
        )
        return fn, (params_abs, specs["batch"])

    # decode: serve_step over the full cache
    cache_abs = specs["cache"]
    cspecs = cache_specs(cfg, plan, cache_abs, shape.global_batch)
    c_sh = _named(mesh, cspecs)
    fn = jax.jit(
        lambda p, c, b, pos: decode_step(p, c, b, pos, cfg, sh=sh),
        in_shardings=(p_sh, c_sh, _named(mesh, bspecs), rep),
        out_shardings=(rep, c_sh),
        donate_argnums=(1,),
    )
    return fn, (params_abs, cache_abs, specs["batch"], jax.ShapeDtypeStruct((), jnp.int32))


def _cost_numbers(compiled) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())
    flops = float(cost.get("flops", 0.0))
    byt = float(
        cost.get("bytes accessed", 0.0)
        or sum(v for k, v in cost.items() if k.startswith("bytes accessed"))
    )
    return {"flops": flops, "bytes": byt, "coll": coll}


def calibrated_costs(cfg, shape, mesh, force_big: bool) -> dict:
    """Exact per-device costs via two *unrolled* small-depth compiles.

    XLA's cost_analysis counts while-loop (lax.scan) bodies once, so the
    scanned production compile undercounts per-layer flops/bytes/collectives
    by ~n_superblocks x.  Costs are linear in layer count L, so we compile
    unrolled models at L=p and L=2p (p = pattern length, full widths) and
    extrapolate: cost(L) = overhead + per_layer * L.
    """
    import dataclasses

    p = len(cfg.attn_pattern)
    nums = []
    for L in (p, 2 * p):
        c = dataclasses.replace(cfg, n_layers=L, scan_layers=False)
        fn, args = build_cell(c, shape, mesh, force_big=force_big)
        nums.append(_cost_numbers(fn.lower(*args).compile()))
    L1, L2, L = p, 2 * p, cfg.n_layers

    def lin(v1, v2):
        slope = (v2 - v1) / (L2 - L1)
        return max(v1 + slope * (L - L1), 0.0)

    out = {
        "flops": lin(nums[0]["flops"], nums[1]["flops"]),
        "bytes": lin(nums[0]["bytes"], nums[1]["bytes"]),
        "coll": {
            k: lin(nums[0]["coll"][k], nums[1]["coll"][k])
            for k in nums[0]["coll"]
            if k != "counts"
        },
    }
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, hw: HW = HW()) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    force_big = cfg.param_count() > 8e9
    t0 = time.time()
    fn, args = build_cell(cfg, shape, mesh)
    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    raw = _cost_numbers(compiled)
    cal = calibrated_costs(cfg, shape, mesh, force_big)

    flops_dev = cal["flops"]
    bytes_dev = cal["bytes"]
    coll = cal["coll"]
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mflops = model_flops(cfg, shape.kind, tokens)
    report = roofline_report(flops_dev, bytes_dev, coll["total"], hw=hw)

    out = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "t_lower_s": round(t_lower, 1),
        "t_compile_s": round(t_compile, 1),
        "flops_per_device": flops_dev,
        "hbm_bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll["total"],
        "collectives": {k: v for k, v in coll.items() if k != "counts"},
        "collective_counts": raw["coll"]["counts"],
        "raw_scanned": {
            "flops": raw["flops"],
            "bytes": raw["bytes"],
            "coll_total": raw["coll"]["total"],
        },
        "model_flops_total": mflops,
        "model_flops_per_device": mflops / n_dev,
        "useful_flops_ratio": (mflops / n_dev) / flops_dev if flops_dev else 0.0,
        "roofline": report,
    }
    if mem is not None:
        out["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
        arg_b = out["memory"]["argument_bytes"] or 0
        tmp_b = out["memory"]["temp_bytes"] or 0
        out["fits_v5e"] = bool(arg_b + tmp_b < V5E_HBM)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shapes", default="assigned")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--fail-fast", action="store_true")
    args = ap.parse_args()

    archs = list(ALL_ARCHS) if args.arch == "all" else args.arch.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    failures = []
    for arch in archs:
        shape_names = (
            [s.name for s in shapes_for(arch)]
            if args.shapes == "assigned"
            else args.shapes.split(",")
        )
        for shape_name in shape_names:
            for mp in meshes:
                tag = f"{arch}_{shape_name}_{'mp' if mp else 'sp'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[skip] {tag} (exists)")
                    continue
                try:
                    res = run_cell(arch, shape_name, mp)
                    with open(path, "w") as f:
                        json.dump(res, f, indent=1)
                    r = res["roofline"]
                    print(
                        f"[ok] {tag}: compile {res['t_compile_s']}s "
                        f"comp={r['compute_s']:.3e}s mem={r['memory_s']:.3e}s "
                        f"coll={r['collective_s']:.3e}s dom={r['dominant']} "
                        f"useful={res['useful_flops_ratio']:.2f}",
                        flush=True,
                    )
                except Exception as e:  # noqa: BLE001
                    failures.append((tag, repr(e)))
                    print(f"[FAIL] {tag}: {e}", flush=True)
                    traceback.print_exc()
                    if args.fail_fast:
                        raise
    if failures:
        print(f"\n{len(failures)} failures:")
        for tag, err in failures:
            print(" ", tag, err[:200])
        raise SystemExit(1)
    print("\nall dry-run cells passed")


if __name__ == "__main__":
    main()
