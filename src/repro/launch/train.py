"""Training launcher: data pipeline -> sharded train step -> checkpoints.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --tiny \
      --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
  PYTHONPATH=src python -m repro.launch.train --arch mixtral-8x7b --tiny \
      --router pkg_potc --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b --tiny \
      --router w_choices --steps 2   # adaptive W-Choices expert routing

On a real TPU slice this same entry point runs the production mesh
(--mesh data,model); on CPU it defaults to a single device.  Fault tolerance:
--fail-at N injects a failure; rerunning the same command resumes from the
latest checkpoint and replays the stream deterministically.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true", help="reduced same-family config")
    ap.add_argument(
        "--router",
        default=None,
        choices=[None, "topk_aux", "pkg_potc", "d_choices", "w_choices"],
    )
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--partitioner", default="pkg", choices=["pkg", "kg", "sg"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    from repro.checkpoint import CheckpointManager
    from repro.configs import TrainConfig, get_config, make_tiny
    from repro.data import PKGDataPipeline, SyntheticCorpus
    from repro.models import init_params
    from repro.optim import adamw_init
    from repro.train import TrainingHarness, make_train_step

    cfg = get_config(args.arch)
    if args.tiny:
        cfg = make_tiny(cfg)
    if args.router:
        cfg = dataclasses.replace(cfg, router=args.router)
    assert cfg.frontend == "tokens", "token-frontend archs only in this driver"

    tcfg = TrainConfig(
        learning_rate=args.lr,
        total_steps=args.steps,
        warmup_steps=max(args.steps // 20, 2),
        microbatches=args.microbatches,
    )
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} params={n:,} devices={jax.device_count()}")

    pipe = PKGDataPipeline(
        batch_size=args.batch,
        seq_len=args.seq,
        vocab_size=cfg.vocab_size,
        partitioner=args.partitioner,
        corpus=SyntheticCorpus(cfg.vocab_size, seed=args.seed),
        seed=args.seed,
    )
    manager = CheckpointManager(args.ckpt_dir, keep=3)
    step = jax.jit(make_train_step(cfg, tcfg))
    harness = TrainingHarness(
        step, pipe, manager, checkpoint_every=args.ckpt_every, fail_at_step=args.fail_at
    )
    params, opt, history = harness.run(
        params, adamw_init(params), args.steps, log_every=args.log_every
    )
    print(f"done: first-5 loss {history[:5]} last-5 loss {history[-5:]}")


if __name__ == "__main__":
    main()
