"""ShapeDtypeStruct input stands-ins for every (arch × shape) dry-run cell.

`input_specs(cfg, shape)` mirrors shannon/kernels: weak-type-correct,
shardable, zero allocation.  Decode shapes include the full KV-cache structs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.transformer import init_cache

__all__ = ["input_specs", "cache_structs"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def cache_structs(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree matching init_cache (built via eval_shape)."""
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len, dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Returns {'batch': ..., and for decode 'cache': ..., 'pos': ...}."""
    B, S = shape.global_batch, shape.seq_len
    out = {}
    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "audio_stub":
            batch = {"embeds": _sds((B, S, cfg.d_model), jnp.bfloat16)}
            if shape.kind == "train":
                batch["labels"] = _sds((B, S, cfg.n_io_heads), jnp.int32)
        else:
            batch = {"tokens": _sds((B, S), jnp.int32)}
            if shape.kind == "train":
                batch["labels"] = _sds((B, S), jnp.int32)
        out["batch"] = batch
    else:  # decode: one new token against a seq_len-deep cache
        if cfg.frontend == "audio_stub":
            out["batch"] = {"embeds": _sds((B, 1, cfg.d_model), jnp.bfloat16)}
        else:
            out["batch"] = {"tokens": _sds((B, 1), jnp.int32)}
        out["cache"] = cache_structs(cfg, B, S)
        out["pos"] = _sds((), jnp.int32)
    return out
