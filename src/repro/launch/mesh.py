"""Production mesh construction (function, not module-level constant, so
importing never touches jax device state)."""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod ("data","model") or 2x16x16 ("pod","data","model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many local devices exist (tests/examples)."""
    return jax.make_mesh((data, model), ("data", "model"))
