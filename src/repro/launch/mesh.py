"""Production mesh construction (function, not module-level constant, so
importing never touches jax device state)."""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "make_stream_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod ("data","model") or 2x16x16 ("pod","data","model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many local devices exist (tests/examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_stream_mesh(n_shards: int = 1):
    """1-D ("data",) mesh for the sharded stream router
    (parallel/sharded_router.py): one shard of the key stream per device,
    loads synced by psum over "data" every load-sync epoch."""
    n_dev = jax.local_device_count()
    if n_shards > n_dev:
        raise ValueError(
            f"n_shards={n_shards} exceeds {n_dev} local device(s); "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=N on CPU"
        )
    return jax.make_mesh((n_shards,), ("data",))
