"""Parameter definitions with logical sharding axes (single source of truth).

Model code builds pytrees of ParamDef(shape, logical_axes, init); the launcher
materializes arrays (`materialize`) and derives jax.sharding.PartitionSpec
trees (`partition_specs`) from a logical->mesh rule table, MaxText-style.

Logical axis vocabulary:
  embed    residual/model dim           -> FSDP ("data" [+ "pod"]) or None
  ffn      MLP hidden dim               -> "model" (TP)
  heads    attention q-head dim         -> "model" when divisible, else None
  kv       kv-head dim                  -> "model" when divisible, else None
  vocab    vocabulary dim               -> "model" (TP)
  experts  MoE expert dim               -> "model" (EP) when divisible
  layers   stacked-scan layer dim       -> None (never sharded)
  conv     conv kernel width            -> None
  rnn      recurrent state dim          -> "model" when divisible
  state    SSM state dim                -> None
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

__all__ = [
    "ParamDef",
    "materialize",
    "partition_specs",
]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Declarative parameter: shape + logical axes + initializer.

    `fan_in` must be set explicitly for >2-D weights whose contraction dim is
    not shape[-2] (e.g. attention (d, H, hd) contracts over d) — the default
    heuristic mis-scales them and init variance compounds exponentially with
    depth (see tests/test_init.py).
    """

    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | embed | small
    scale: float = 1.0
    dtype: jnp.dtype = jnp.float32
    fan_in: Optional[int] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_leaf(d: ParamDef, key: jax.Array, param_dtype) -> jax.Array:
    dt = param_dtype if d.init != "zeros" else param_dtype
    if d.init == "zeros":
        return jnp.zeros(d.shape, dt)
    if d.init == "ones":
        return jnp.ones(d.shape, dt)
    # fan-in scaled normal; "embed" uses 1/sqrt(d_model) (tied-logit safe),
    # "small" uses 0.02
    if d.init == "embed":
        std = 1.0 / np.sqrt(d.shape[-1])
    elif d.init == "small":
        std = 0.02
    else:
        fan_in = d.fan_in or (d.shape[-2] if len(d.shape) >= 2 else d.shape[-1])
        std = d.scale / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(dt)


def materialize(defs, key: jax.Array, param_dtype=jnp.float32):
    """ParamDef pytree -> array pytree (deterministic per-leaf keys)."""
    leaves, treedef = jax.tree_util.tree_flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )
    keys = jax.random.split(key, len(leaves))
    arrs = [_init_leaf(d, k, param_dtype) for d, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, arrs)


def abstract(defs, param_dtype=jnp.float32):
    """ParamDef pytree -> ShapeDtypeStruct pytree (no allocation)."""
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, param_dtype),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def partition_specs(defs, rules: dict):
    """ParamDef pytree -> PartitionSpec pytree via the rule table."""

    def leaf(d: ParamDef):
        return P(*[rules.get(a, None) for a in d.axes])

    return jax.tree_util.tree_map(
        leaf, defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )
