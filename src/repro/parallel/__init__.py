# NOTE: repro.parallel.sharding imports the model registry, so it must be
# imported directly (repro.parallel.sharding) to avoid a circular import
# through the model modules, which only need ParamDef from .spec.
from repro.parallel.spec import ParamDef, abstract, materialize, partition_specs
