"""Memory-flat chunked streaming execution over the shared route core.

The one-shot Pallas routers (kernels/pkg_route.py, kernels/adaptive_route.py)
materialize the whole stream on device and scan it once — fine at 1e5
messages, impossible at 1e8.  This module routes *unbounded* chunk iterators
through the SAME block-greedy core (kernels/route_core.route_block) with
constant device memory:

* **One fixed-shape jitted chunk step** per static configuration, compiled
  once and reused for every chunk of the stream (the final partial chunk is
  padded and mask-recovered, so a single executable serves all chunks).  The
  step is a ``lax.scan`` of ``route_block`` over ``chunk // block`` vector
  blocks; per-chunk cost is O(chunk) and independent of stream position.
* **A donated carry** — ``donate_argnums`` on the (loads row, Space-Saving
  summary, block counter) tuple — so the carry buffers are updated in place
  and device memory stays flat however many chunks stream through.
* **Double-buffered ingestion**: chunk k+1 is rebuffered and shipped with an
  async ``jax.device_put`` while chunk k's step executes, so host->device
  transfer overlaps routing.

Bit-exactness contract (tests/test_chunked.py): routing a stream through any
chunk size — including chunk sizes that force a padded final chunk — yields
the SAME assignment as the one-shot scan, because the carry (integer counts
in f32 + the OnlineSS summary arrays) is exactly the scan state the one-shot
path threads internally, and pad lanes are masked out of the histogram, the
tracker, and the water-fill (they can never perturb a real decision).  The
one-shot references are:

  pkg        -> kernels.pkg_route(chunk=N)   (same block size)
  d_choices  -> estimation.online_head_tables + adaptive_route_online
  w_choices  -> same, with any_worker head tables and w_mode=True

Per-block semantics for the adaptive policies mirror ``online_head_tables``
exactly: emit the head table from the summary *before* the block (stale by
<= block messages), route, cond-decay on period boundaries, then update the
tracker per element — one shared emit (estimation.online_ss_head_table), so
the chunked and one-shot paths cannot drift.

``ChunkedShardedRouter`` extends the same idea across the sharded router's
load-sync epochs: each chunk is exactly one epoch (n_shards * sync_period *
block keys), routed by the same vmap-of-``_block_scan``-plus-summed-deltas
program as ``ref_sharded_route``, with the global loads row carried across
chunks — chunk boundaries ARE the load-sync boundaries.

Import directly (``from repro.parallel.chunked_driver import ChunkedRouter``);
like parallel.sharding this module is not re-exported from repro.parallel.
"""
from __future__ import annotations

import warnings
from typing import Callable, Iterable, Iterator, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.estimation import (
    OnlineSS,
    online_ss_decay,
    online_ss_head_table,
    online_ss_init,
    online_ss_update,
)
from repro.core.hashing import derive_seeds
from repro.kernels.route_core import (
    MASK,
    hash_candidates,
    head_table_ncand,
    route_block,
)

__all__ = [
    "POLICIES",
    "ChunkedRouter",
    "ChunkedShardedRouter",
    "clear_step_cache",
]

POLICIES = ("pkg", "d_choices", "w_choices")


class _StepConfig(NamedTuple):
    """Full static configuration of one compiled chunk step (the cache key)."""

    policy: str
    chunk: int
    block: int
    n_workers: int
    d: int
    d_max: int
    has_cap: bool
    theta: Optional[float]
    slack: float
    min_count: int
    decay_period: int
    ss_capacity: int


# Compiled chunk steps, keyed on the full static config.  _SEEN_SHAPES maps
# the *logical* config (n_workers, policy, capacities-is-None) to the chunk
# shapes already compiled for it, so sweeping chunk sizes warns instead of
# silently retracing (satellite contract; benches that sweep on purpose catch
# the warning).
_STEP_CACHE: dict = {}
_SEEN_SHAPES: dict = {}


def clear_step_cache() -> None:
    """Drop all compiled steps + recompile bookkeeping (tests use this)."""
    _STEP_CACHE.clear()
    _SEEN_SHAPES.clear()


def _warn_new_shape(logical, shape, kind: str) -> None:
    seen = _SEEN_SHAPES.setdefault(logical, set())
    if seen and shape not in seen:
        warnings.warn(
            f"chunked_driver: compiling a new {kind} step for shape {shape} "
            f"(config {logical} already has compiled shapes {sorted(seen)}) "
            "— each swept chunk size traces its own executable; reuse one "
            "chunk size to avoid recompilation",
            stacklevel=3,
        )
    seen.add(shape)


def _build_step(cfg: _StepConfig) -> Callable:
    """One fixed-shape chunk step: scan route_block over the chunk's blocks.

    step(carry, keys (chunk,) i32, valid (chunk,) i32, seeds, icap) ->
    (carry', choices (chunk,)).  carry = (loads (1, n_workers) f32, OnlineSS
    or None, global block counter () i32).  Pad lanes (valid == 0) route as
    tail messages but are masked out of the histogram, the tracker update,
    and (by never carrying W_SENTINEL) the water-fill rank sequence — they
    cannot perturb any real decision, which is what makes a padded final
    chunk bit-exact to the unpadded one-shot scan.
    """
    nblk = cfg.chunk // cfg.block
    w_mode = cfg.policy == "w_choices"
    adaptive = cfg.policy != "pkg"
    eid = jnp.arange(cfg.n_workers, dtype=jnp.int32)

    def step(carry, keys_c, valid_c, seeds, icap):
        kb_all = keys_c.astype(jnp.int32).reshape(nblk, cfg.block)
        vb_all = valid_c.astype(jnp.int32).reshape(nblk, cfg.block)

        def blk(c, inp):
            loads, state, b = c
            kb, vb = inp
            if adaptive:
                # Table emitted from the state BEFORE this block (stale by
                # <= block messages) — online_head_tables' exact emit.
                tk, tn = online_ss_head_table(
                    state, cfg.n_workers, d=cfg.d, d_max=cfg.d_max,
                    theta=cfg.theta, slack=cfg.slack,
                    min_count=cfg.min_count, any_worker=w_mode,
                )
                nc = head_table_ncand(kb, tk, tn, cfg.d, cfg.d_max)
                nc = jnp.where(vb > 0, nc, jnp.int32(cfg.d))
            else:
                nc = None
            cand = hash_candidates(kb, seeds, cfg.n_workers)
            choice, _, _, _ = route_block(
                cand, nc, loads, n_entities=cfg.n_workers, w_mode=w_mode,
                inv_cap=icap,
            )
            # Masked histogram instead of route_block's own: pad lanes must
            # not count.  Integer 0/1 sums in f32 are exact, so an all-valid
            # block reproduces route_block's update bit-for-bit.
            hist = ((choice[:, None] == eid) & (vb[:, None] > 0))
            loads = loads + hist.astype(jnp.float32).sum(axis=0)[None, :]
            if adaptive:
                if cfg.decay_period > 0:
                    do = (b * cfg.block) % cfg.decay_period < cfg.block
                    state = lax.cond(
                        (b > 0) & do, online_ss_decay, lambda s: s, state
                    )

                def upd(s, kv):
                    k, v = kv
                    # weight=0 would still evict a slot; skip pads entirely
                    return lax.cond(
                        v > 0, lambda s: online_ss_update(s, k),
                        lambda s: s, s,
                    ), None

                state = lax.scan(upd, state, (kb, vb))[0]
            return (loads, state, b + jnp.int32(1)), choice

        carry, choices = lax.scan(blk, carry, (kb_all, vb_all))
        return carry, choices.reshape(-1)

    return step


def _get_step(cfg: _StepConfig) -> Callable:
    if cfg not in _STEP_CACHE:
        _warn_new_shape(
            (cfg.n_workers, cfg.policy, cfg.has_cap), cfg.chunk, "chunk"
        )
        _STEP_CACHE[cfg] = jax.jit(_build_step(cfg), donate_argnums=(0,))
    return _STEP_CACHE[cfg]


class ChunkedRouter:
    """Route an unbounded key stream in fixed-shape chunks, flat memory.

    Policies: "pkg" (fixed d candidates), "d_choices" (adaptive d(k) from a
    carried Space-Saving summary), "w_choices" (head keys to the global
    water-fill argmin).  The carry — loads row, summary, block counter —
    persists across route_stream calls, so a stream may be fed in any number
    of pieces; assignments are bit-exact to the one-shot scan for EVERY
    chunk size as long as padding only happens at the true end of the stream
    (route_stream rebuffers arbitrary incoming pieces into exact chunk-sized
    steps, so only its final flush pads; feed whole streams, or split at
    multiples of `block` to keep block boundaries aligned across runs).

    `capacities` ((n_workers,) strictly positive) switches every argmin to
    capacity-normalized loads, exactly as the one-shot kernels do.
    """

    def __init__(
        self,
        n_workers: int,
        policy: str = "pkg",
        *,
        d: int = 2,
        d_max: int = 8,
        chunk: int = 8192,
        block: int = 128,
        seed: int = 0,
        capacities=None,
        ss_capacity: int = 256,
        theta: Optional[float] = None,
        slack: float = 2.0,
        min_count: int = 8,
        decay_period: int = 0,
    ):
        if policy not in POLICIES:
            raise ValueError(f"policy {policy!r} not in {POLICIES}")
        if chunk % block:
            raise ValueError(f"chunk={chunk} must divide by block={block}")
        if policy == "d_choices":
            d_max = max(int(min(d_max, n_workers)), d)
        else:
            d_max = d  # pkg / w_choices hash exactly d candidate lanes
        self.n_workers = int(n_workers)
        self.policy = policy
        self.chunk = int(chunk)
        self.block = int(block)
        self.d = int(d)
        self.d_max = int(d_max)
        self._cfg = _StepConfig(
            policy=policy, chunk=self.chunk, block=self.block,
            n_workers=self.n_workers, d=self.d, d_max=self.d_max,
            has_cap=capacities is not None,
            theta=None if theta is None else float(theta),
            slack=float(slack), min_count=int(min_count),
            decay_period=int(decay_period), ss_capacity=int(ss_capacity),
        )
        self._step = _get_step(self._cfg)
        self._seeds = derive_seeds(seed, self.d_max)
        if capacities is None:
            self._icap = None
        else:
            cap = np.asarray(capacities, np.float32).reshape(-1)
            if cap.shape != (self.n_workers,) or not (cap > 0).all():
                raise ValueError(
                    f"capacities must be ({self.n_workers},) strictly positive"
                )
            self._icap = jnp.asarray(1.0 / cap).reshape(1, self.n_workers)
        state = online_ss_init(ss_capacity) if policy != "pkg" else None
        self._carry = (
            jnp.zeros((1, self.n_workers), jnp.float32),
            state,
            jnp.int32(0),
        )
        self._valid_full = jax.device_put(np.ones(self.chunk, np.int32))
        self._killed: dict[int, float] = {}
        self.n_routed = 0

    # -- observability ------------------------------------------------------

    @property
    def loads(self) -> np.ndarray:
        """Current worker loads (n_workers,) f32 (killed workers read MASK)."""
        return np.asarray(self._carry[0]).reshape(-1)

    @property
    def tracker(self) -> Optional[OnlineSS]:
        """The carried Space-Saving summary (None for policy='pkg')."""
        return self._carry[1]

    def state_bytes(self) -> int:
        """Bytes of carried routing state — THE flat-memory number: constant
        in both stream length and distinct-key count (bytes/key -> 0)."""
        return sum(
            leaf.nbytes for leaf in jax.tree_util.tree_leaves(self._carry)
        )

    # -- failure handling ---------------------------------------------------

    def kill(self, worker: int) -> None:
        """Mask a worker mid-stream: its loads lane becomes the f32 MASK
        sentinel, so no candidate/water-fill argmin can pick it (unless every
        candidate is dead).  Takes effect at the next chunk step — kill
        between route_stream calls for a deterministic boundary."""
        if worker in self._killed:
            return
        loads = np.asarray(self._carry[0]).copy()
        self._killed[int(worker)] = float(loads[0, worker])
        loads[0, worker] = MASK
        self._set_loads(loads)

    def revive(self, worker: int) -> None:
        """Restore a killed worker at its pre-kill load (stored host-side —
        f32 cannot recover it from MASK + count)."""
        loads = np.asarray(self._carry[0]).copy()
        loads[0, worker] = self._killed.pop(int(worker))
        self._set_loads(loads)

    def _set_loads(self, loads: np.ndarray) -> None:
        _, state, b = self._carry
        self._carry = (jnp.asarray(loads, jnp.float32), state, b)

    # -- routing ------------------------------------------------------------

    def _device_pieces(
        self, chunks: Iterable[np.ndarray]
    ) -> Iterator[tuple[jnp.ndarray, jnp.ndarray, int]]:
        """Rebuffer arbitrary-size chunks into exact `chunk`-size pieces and
        device_put them (async — overlaps the in-flight step's compute).
        Only the final piece may be partial; it ships zero-padded with a
        valid mask."""
        buf = np.empty(self.chunk, np.int32)
        fill = 0
        for arr in chunks:
            arr = np.asarray(arr, np.int32).reshape(-1)
            off = 0
            while off < len(arr):
                n = min(len(arr) - off, self.chunk - fill)
                buf[fill : fill + n] = arr[off : off + n]
                fill += n
                off += n
                if fill == self.chunk:
                    yield jax.device_put(buf.copy()), self._valid_full, fill
                    fill = 0
        if fill:
            keys = np.zeros(self.chunk, np.int32)
            keys[:fill] = buf[:fill]
            valid = np.zeros(self.chunk, np.int32)
            valid[:fill] = 1
            yield jax.device_put(keys), jax.device_put(valid), fill

    def route_stream(
        self,
        chunks: Union[np.ndarray, Iterable[np.ndarray]],
        on_chunk: Optional[Callable[[np.ndarray], None]] = None,
    ) -> Union[np.ndarray, int]:
        """Route a stream given as one array or an iterator of arrays.

        Double-buffered: while chunk k's step runs on device, chunk k+1 is
        rebuffered and device_put, and chunk k-1's assignments are pulled to
        host.  Returns the concatenated assignment array — or, with
        `on_chunk` (called with each piece's (n_valid,) int32 assignments in
        order), just the number of events routed, so a 1e8-event run never
        holds more than one chunk of output (flat RSS).
        """
        if isinstance(chunks, np.ndarray) or not hasattr(chunks, "__iter__"):
            chunks = [np.asarray(chunks)]
        outs: Optional[list] = [] if on_chunk is None else None
        pending = None
        n = 0
        it = self._device_pieces(chunks)
        cur = next(it, None)
        while cur is not None:
            keys_d, valid_d, n_valid = cur
            self._carry, choices = self._step(
                self._carry, keys_d, valid_d, self._seeds, self._icap
            )
            cur = next(it, None)  # prefetch overlaps the async step above
            if pending is not None:
                self._emit(pending, outs, on_chunk)
            pending = (choices, n_valid)
            n += n_valid
        if pending is not None:
            self._emit(pending, outs, on_chunk)
        self.n_routed += n
        if outs is not None:
            return (
                np.concatenate(outs) if outs else np.empty(0, np.int32)
            )
        return n

    @staticmethod
    def _emit(pending, outs, on_chunk) -> None:
        choices, n_valid = pending
        # scatter-index recovery is a trim: pads are always the tail lanes
        a = np.asarray(choices[:n_valid], dtype=np.int32)
        if on_chunk is not None:
            on_chunk(a)
        else:
            outs.append(a)


# ---------------------------------------------------------------------------
# Chunked sharded routing: chunk == load-sync epoch.
# ---------------------------------------------------------------------------


class _ShardedStepConfig(NamedTuple):
    n_workers: int
    n_shards: int
    sync_period: int
    block: int
    d_max: int
    w_mode: bool
    has_nc: bool
    has_cap: bool


def _build_sharded_step(cfg: _ShardedStepConfig) -> Callable:
    """One load-sync epoch from a carried global loads row: vmap the shared
    per-shard _block_scan and sum the deltas — the exact epoch body of
    sharded_router.ref_sharded_route, with the scan-over-epochs replaced by
    the host loop feeding chunks."""
    from repro.parallel.sharded_router import _block_scan

    S, P, B = cfg.n_shards, cfg.sync_period, cfg.block

    def step(loads_g, keys, nc, seeds, icap):
        cand = hash_candidates(
            keys.astype(jnp.int32).reshape(-1), seeds, cfg.n_workers
        ).reshape(S, P, B, cfg.d_max)
        ncr = None if not cfg.has_nc else nc.astype(jnp.int32).reshape(S, P, B)

        def per_shard(c_s, n_s=None):
            return _block_scan(
                loads_g, c_s, n_s, n_workers=cfg.n_workers,
                w_mode=cfg.w_mode, inv_cap=icap,
            )

        if ncr is None:
            loads_end, choices = jax.vmap(per_shard)(cand)
        else:
            loads_end, choices = jax.vmap(per_shard)(cand, ncr)
        delta = (loads_end - loads_g).sum(axis=0)  # integer counts: exact
        return loads_g + delta, choices.reshape(-1)

    return step


def _get_sharded_step(cfg: _ShardedStepConfig) -> Callable:
    if cfg not in _STEP_CACHE:
        _warn_new_shape(
            (cfg.n_workers, "sharded", cfg.has_cap),
            (cfg.n_shards, cfg.sync_period, cfg.block),
            "sharded epoch",
        )
        _STEP_CACHE[cfg] = jax.jit(
            _build_sharded_step(cfg), donate_argnums=(0,)
        )
    return _STEP_CACHE[cfg]


class ChunkedShardedRouter:
    """Chunked streaming over the sharded router: every chunk is exactly one
    load-sync epoch (n_shards * sync_period * block keys, laid out
    [shard][block-in-epoch][lane]), so chunk boundaries align with the epoch
    psum by construction and the carried loads row IS the globally-synced
    histogram.  k chunks through this router are bit-exact to
    ref_sharded_route over the same stream in its shard-major layout
    (differential in tests/test_chunked.py).

    n_cand follows sharded_route's contract: None for plain PKG, per-key
    counts (W_SENTINEL heads under w_mode=True) otherwise.
    """

    def __init__(
        self,
        n_workers: int,
        *,
        d_max: int = 2,
        n_shards: int = 1,
        sync_period: int = 1,
        block: int = 128,
        seed: int = 0,
        w_mode: bool = False,
        has_n_cand: bool = False,
        capacities=None,
    ):
        self.n_workers = int(n_workers)
        self.epoch_chunk = int(n_shards) * int(sync_period) * int(block)
        self._cfg = _ShardedStepConfig(
            n_workers=self.n_workers, n_shards=int(n_shards),
            sync_period=int(sync_period), block=int(block),
            d_max=int(d_max), w_mode=bool(w_mode),
            has_nc=bool(has_n_cand or w_mode),
            has_cap=capacities is not None,
        )
        self._step = _get_sharded_step(self._cfg)
        self._seeds = derive_seeds(seed, int(d_max))
        if capacities is None:
            self._icap = None
        else:
            cap = np.asarray(capacities, np.float32).reshape(-1)
            self._icap = jnp.asarray(1.0 / cap).reshape(1, self.n_workers)
        self._loads = jnp.zeros((1, self.n_workers), jnp.float32)
        self.n_routed = 0

    @property
    def loads(self) -> np.ndarray:
        return np.asarray(self._loads).reshape(-1)

    def route_chunk(self, keys, n_cand=None) -> np.ndarray:
        """Route exactly one epoch of keys (len == epoch_chunk).  For a final
        partial epoch, pad with repeated tail keys first (the
        _sharded_dispatch contract: pads route and count, bounded by one
        epoch of staleness) and trim the returned assignments."""
        keys = np.asarray(keys, np.int32).reshape(-1)
        if keys.shape[0] != self.epoch_chunk:
            raise ValueError(
                f"chunk length {keys.shape[0]} != epoch_chunk "
                f"{self.epoch_chunk} (chunks must align with load-sync epochs)"
            )
        if self._cfg.has_nc:
            if n_cand is None:
                raise ValueError("this router was built with has_n_cand/w_mode")
            nc = jnp.asarray(np.asarray(n_cand, np.int32).reshape(-1))
        else:
            nc = None
        self._loads, choices = self._step(
            self._loads, jnp.asarray(keys), nc, self._seeds, self._icap
        )
        self.n_routed += self.epoch_chunk
        return np.asarray(choices, dtype=np.int32)
