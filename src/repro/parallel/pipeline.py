"""GPipe-style pipeline parallelism over a mesh "stage" axis (shard_map).

Each device (or device group) holds one stage's parameters; microbatches
stream through the stages via lax.ppermute inside a lax.scan over the
M + S - 1 schedule steps.  Differentiable end to end (autodiff through
ppermute/scan), so the same primitive serves training.

This composes with the other axes: a (stage, data, model) mesh runs PP x DP
x TP; the dry-run meshes use (pod, data, model) since the assigned shapes
fit without PP, but the primitive + parity tests keep the capability honest
(DESIGN.md §6).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["pipeline_forward", "make_pipelined_fn"]


def pipeline_forward(
    stage_fn: Callable,
    stage_params,
    x_microbatches: jnp.ndarray,
    axis_name: str = "stage",
) -> jnp.ndarray:
    """Run microbatches through S pipeline stages (call inside shard_map).

    stage_fn(params, x) -> y, same shape; stage_params are THIS device's.
    x_microbatches: (M, mb, ...), replicated across the stage axis.
    Returns (M, mb, ...) outputs (replicated; produced on the last stage and
    broadcast with a psum).
    """
    # jax.lax.axis_size only exists in newer jax; psum(1) is the portable form
    S = lax.psum(1, axis_name)
    sidx = lax.axis_index(axis_name)
    M = x_microbatches.shape[0]
    T = M + S - 1
    perm = [(i, (i + 1) % S) for i in range(S)]

    def step(carry, t):
        buf, outs = carry
        inject = x_microbatches[jnp.minimum(t, M - 1)]
        cur = jnp.where(sidx == 0, inject, buf)
        y = stage_fn(stage_params, cur)
        nxt = lax.ppermute(y, axis_name, perm)
        m_out = t - (S - 1)
        idx = jnp.maximum(m_out, 0)
        emit = jnp.logical_and(sidx == S - 1, m_out >= 0)
        outs = outs.at[idx].set(jnp.where(emit, y, outs[idx]))
        return (nxt, outs), None

    buf0 = jnp.zeros_like(x_microbatches[0])
    outs0 = jnp.zeros_like(x_microbatches)
    (_, outs), _ = lax.scan(step, (buf0, outs0), jnp.arange(T))
    # broadcast the last stage's outputs to every stage
    outs = lax.psum(jnp.where(sidx == S - 1, outs, jnp.zeros_like(outs)), axis_name)
    return outs


def make_pipelined_fn(stage_fn: Callable, mesh, n_stages: int, axis_name: str = "stage"):
    """Wrap stage_fn into a jit'd (stacked_params, x_microbatches) -> outs.

    stacked_params: leading dim n_stages on every leaf (stage s's slice lives
    on stage s); x_microbatches (M, mb, ...) replicated.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def inner(stacked_params, x_mb):
        my = jax.tree_util.tree_map(lambda a: a[0], stacked_params)
        return pipeline_forward(stage_fn, my, x_mb, axis_name)

    fn = shard_map(
        inner,
        mesh=mesh,
        in_specs=(P(axis_name), P()),
        out_specs=P(),
        check_rep=False,
    )
    return jax.jit(fn)
