"""Concrete sharding strategy per (arch config × shape kind × mesh).

Strategy summary (DESIGN.md §6):
  params    FSDP over "data" (+ "pod" for the largest archs) on the embed dim;
            TP over "model" on {d_ff, vocab, experts, rnn, q/kv heads when the
            head count divides the axis}.  When heads cannot TP-shard, the
            attention weights' embed dim shards over (data, model) instead
            ("embed_attn"), keeping state fully sharded over all devices.
  attention head-TP when kv-heads or q-groups divide "model"; otherwise
            sequence parallel (q sequence-sharded, KV gathered).
  MoE       EP over "model" when n_experts divides it, else TP-experts (d_ff).
  activations  batch over ("pod","data"); residual sequence-sharded over
            "model" (SP); logits vocab-sharded; decode caches sharded on the
            sequence axis (context-parallel decode for global_batch < dp).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.transformer import init_defs
from repro.parallel.spec import partition_specs

__all__ = [
    "ShardingPlan",
    "make_plan",
    "param_shardings",
    "make_sharder",
    "batch_specs",
    "cache_specs",
    "stream_shard_specs",
]


def stream_shard_specs(
    has_ncand: bool = True, has_cap: bool = False, has_weights: bool = False
):
    """(in_specs, out_specs) for shard_map-ing the sharded stream router
    (parallel/sharded_router.py) over a ("data",) mesh: the key stream (and
    its per-message candidate counts, when present) split over "data", the
    hash-seed family replicated; assignments split, the synced global loads
    row replicated (it is psum-ed every load-sync epoch).  Optional trailing
    operands, in order: the reciprocal-capacity row (replicated — every
    shard normalizes by the same worker capacities) and the per-shard
    load-sync delta weights (split over "data": each shard reads only its
    own weight)."""
    ins = [P("data")]
    if has_ncand:
        ins.append(P("data"))
    ins.append(P())
    if has_cap:
        ins.append(P())
    if has_weights:
        ins.append(P("data"))
    return tuple(ins), (P("data"), P())


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    mesh_axes: tuple[str, ...]
    tp: int  # size of "model" axis
    dp: int  # product of data-ish axes
    fsdp_axes: tuple[str, ...]
    head_tp: bool  # attention head-TP vs sequence-parallel attention
    kv_shard: bool  # kv heads TP-shardable
    experts_ep: bool
    rnn_tp: bool
    rules: dict  # logical axis -> mesh axis (params)
    axis_sizes: tuple[tuple[str, int], ...] = ()

    def axis_size(self, name: str) -> int:
        return dict(self.axis_sizes).get(name, 1)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def make_plan(
    cfg,
    mesh: Mesh,
    big_arch_fsdp_pod: bool = True,
    force_big: Optional[bool] = None,
    inference: bool = False,
) -> ShardingPlan:
    axes = tuple(mesh.axis_names)
    tp = _axis_size(mesh, "model")
    dp = int(np.prod([_axis_size(mesh, a) for a in ("pod", "data")]))
    # the biggest archs need optimizer state sharded over every device
    big = cfg.param_count() > 8e9 if force_big is None else force_big
    fsdp: tuple[str, ...] = ("data",)
    if big and big_arch_fsdp_pod and "pod" in axes:
        fsdp = ("pod", "data")
    if inference:
        # weight-stationary serving: bf16 params are TP-sharded over "model"
        # and replicated over data — no per-step FSDP gathers (§Perf serve-1).
        # Even deepseek-67b bf16/16-way TP = 8.4 GB/chip fits v5e.
        fsdp = ()
    kv_shard = cfg.n_kv_heads > 0 and cfg.n_kv_heads % tp == 0
    grp = cfg.n_heads // max(cfg.n_kv_heads, 1) if cfg.n_heads else 0
    head_tp = kv_shard or (grp > 0 and grp % tp == 0)
    experts_ep = cfg.n_experts > 0 and cfg.n_experts % tp == 0
    rnn_dim = cfg.rnn_width or (cfg.d_inner if cfg.ssm_state else 0)
    rnn_tp = rnn_dim > 0 and rnn_dim % tp == 0
    if cfg.d_ff:
        assert cfg.d_ff % tp == 0, (cfg.name, cfg.d_ff, tp)
    assert cfg.vocab_padded % tp == 0, (cfg.name, cfg.vocab_padded, tp)

    # (§Perf iteration attn-1, refuted: replicating non-head-TP attention
    # weights over "model" did not reduce collective bytes — the per-layer
    # weight traffic was already amortized — so they stay fully sharded.)
    rules = {
        "embed": fsdp,
        "embed_attn": fsdp if head_tp else tuple(fsdp) + ("model",),
        "layers": None,
        "conv": None,
        "state": None,
        # EP shards the expert axis; the per-expert d_ff must then stay
        # unsharded (a spec may use each mesh axis once)
        "ffn": None if experts_ep else "model",
        "vocab": "model",
        "heads": "model" if (cfg.n_heads and cfg.n_heads % tp == 0 and head_tp) else None,
        "kv": "model" if kv_shard else None,
        "experts": "model" if experts_ep else None,
        "rnn": "model" if rnn_tp else None,
        None: None,
    }
    # drop axes absent from this mesh (e.g. "pod" on the single-pod mesh)
    def _f(v):
        if v is None:
            return None
        if isinstance(v, str):
            return v if v in axes else None
        vv = tuple(a for a in v if a in axes)
        return vv if vv else None

    rules = {k: _f(v) for k, v in rules.items()}
    sizes = tuple((a, _axis_size(mesh, a)) for a in axes)
    return ShardingPlan(
        axes, tp, dp, fsdp, head_tp, kv_shard, experts_ep, rnn_tp, rules, sizes
    )


def param_shardings(cfg, mesh: Mesh, plan: Optional[ShardingPlan] = None):
    """PartitionSpec pytree for the model parameters."""
    plan = plan or make_plan(cfg, mesh)
    defs = init_defs(cfg)
    return partition_specs(defs, plan.rules)


def _dp(plan: ShardingPlan):
    dp = tuple(a for a in ("pod", "data") if a in plan.mesh_axes)
    return dp if dp else None


def make_sharder(cfg, mesh: Mesh, plan: ShardingPlan, shape_kind: str, global_batch: int):
    """Return sh(name, x): named with_sharding_constraint hook for model code."""
    dp = _dp(plan)
    tp = "model" if "model" in plan.mesh_axes else None
    dp_size = plan.dp
    batch_sharded = global_batch % max(dp_size, 1) == 0 and global_batch >= dp_size
    bax = dp if batch_sharded else None
    seq_ax = tp if shape_kind in ("train", "prefill") else None
    # context-parallel decode: tiny batches shard the cache sequence axis over
    # every mesh axis instead of the batch
    cache_seq_ax = tp if batch_sharded else tuple(
        a for a in ("pod", "data", "model") if a in plan.mesh_axes
    )

    grp = cfg.n_heads // max(cfg.n_kv_heads, 1) if cfg.n_heads else 0
    if shape_kind == "decode":
        q_spec = P(bax, None, None, None, None)
    elif plan.kv_shard:
        q_spec = P(bax, None, "model", None, None)  # kv-head TP
    elif grp and grp % max(plan.tp, 1) == 0:
        q_spec = P(bax, None, None, "model", None)  # q-group TP
    else:
        q_spec = P(bax, tp, None, None, None)  # sequence-parallel attention
    # logits: vocab-TP unless the sequence axis already uses "model" (SP)
    lg_vocab = tp if seq_ax is None else None
    specs = {
        "residual": P(bax, seq_ax, None),
        "logits": P(bax, seq_ax, lg_vocab)
        if cfg.n_io_heads == 1
        else P(bax, seq_ax, None, lg_vocab),
        "q": q_spec,
        "kv_full": P(bax, None, "model" if plan.kv_shard else None, None)
        if shape_kind != "decode"
        else None,
        # SP->TP transition: inside MLP/RNN the feature dim takes "model",
        # so the sequence dim must release it
        "ffn": P(bax, None, tp),
        "rnn": P(bax, None, tp if plan.rnn_tp else None),
        # grouped expert buffers (B, E, C, d): groups follow the dp-sharded
        # batch (shard-local dispatch, §Perf moe-3); E over "model" when EP,
        # else the per-expert ffn dim takes "model" (TP-experts)
        "moe_buffer": P(bax, tp if plan.experts_ep else None, None, None),
        "moe_hidden": P(bax, tp, None, None)
        if plan.experts_ep
        else P(bax, None, None, tp),
        "cache_k": P(bax, cache_seq_ax, None, None),
        "cache_v": P(bax, cache_seq_ax, None, None),
    }

    def sh(name, x):
        spec = specs.get(name)
        if spec is None or mesh.empty:
            return x
        # never constrain more dims than the array has
        if len(spec) > x.ndim:
            return x
        # drop axes a dim cannot divide (e.g. tiny decode-time MoE capacity)
        clean = []
        for dim, entry in zip(x.shape, spec):
            if entry is None:
                clean.append(None)
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            tot = int(np.prod([dict(plan.axis_sizes).get(a, 1) for a in names]))
            clean.append(entry if dim % max(tot, 1) == 0 else None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*clean))
        )

    return sh


def batch_specs(cfg, plan: ShardingPlan, shape_kind: str, global_batch: int) -> dict:
    """PartitionSpecs for the input batch dict."""
    dp = _dp(plan)
    batch_sharded = global_batch % max(plan.dp, 1) == 0 and global_batch >= plan.dp
    bax = dp if batch_sharded else None
    out = {}
    if cfg.frontend == "audio_stub":
        out["embeds"] = P(bax, None, None)
        if shape_kind == "train":
            out["labels"] = P(bax, None, None)
    else:
        out["tokens"] = P(bax, None)
        if shape_kind == "train":
            out["labels"] = P(bax, None)
    return out


def cache_specs(cfg, plan: ShardingPlan, cache, global_batch: int):
    """PartitionSpec pytree matching an init_cache() result.

    Attention caches shard on the sequence axis; SSM/RG-LRU states shard on
    the feature/head axis when divisible.  Leading stacked-layer axes get None.
    """
    dp = _dp(plan)
    batch_sharded = global_batch % max(plan.dp, 1) == 0 and global_batch >= plan.dp
    bax = dp if batch_sharded else None
    tp = "model" if "model" in plan.mesh_axes else None
    cache_seq_ax = tp if batch_sharded else tuple(
        a for a in ("pod", "data", "model") if a in plan.mesh_axes
    )

    def leaf_spec(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", None)) for p in path]
        stacked = leaf.ndim and any(n == "superblocks" for n in names)
        lead = (None,) if stacked else ()
        last = names[-1]
        if last in ("k", "v"):
            seqlen = leaf.shape[1 + len(lead)]
            seq_ax = cache_seq_ax
            if isinstance(seq_ax, tuple):
                tot = int(np.prod([plan.axis_size(a) for a in seq_ax]))
                if seqlen % max(tot, 1):
                    seq_ax = None
            elif seq_ax is not None and seqlen % plan.axis_size(seq_ax):
                seq_ax = None
            return P(*lead, bax, seq_ax, None, None)
        if last == "h":  # rglru (B,w) fp32 or ssd (B,H,N,P)
            if leaf.ndim - len(lead) == 2:
                return P(*lead, bax, tp if plan.rnn_tp else None)
            return P(*lead, bax, tp if plan.rnn_tp else None, None, None)
        if last == "conv":
            return P(*lead, bax, None, tp if plan.rnn_tp else None)
        return P()

    return jax.tree_util.tree_map_with_path(leaf_spec, cache)
