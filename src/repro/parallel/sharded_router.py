"""Multi-device sharded stream router with load-sync epochs (DESIGN.md §6.1).

The paper's two enabling techniques — key splitting and *local* load
estimation — are exactly the contract a device mesh needs: each source can
route with only its own view of the worker loads, so the key stream shards
over a 1-D ``("data",)`` mesh with ``shard_map`` and every shard runs the
SAME block-greedy core as the single-core Pallas routers
(kernels/route_core.route_block — one implementation, zero drift) against
its own local copy of the ``(1, n_workers)`` loads row.

Staleness contract, lifted across chips: the single-core router's loads are
stale by < ``block`` messages (DESIGN.md §2); here each shard's view of the
OTHER shards' loads is additionally stale by < one *load-sync epoch* =
``sync_period`` blocks.  Every ``sync_period`` blocks the per-shard load
deltas are ``psum``-ed over the mesh, so every shard re-synchronizes on the
global histogram — the paper's local-estimation trick with periodic
reconciliation.  ``n_shards=1, sync_period=1`` replays the single-core
kernel bit-exactly (the differential contract in
tests/test_sharded_router.py); larger ``sync_period`` trades collective
bytes for imbalance, a curve bench_sharded_router.py measures.

Two formulations, bit-identical by construction (integer counts in f32 are
exact under any reduction order):

* ``sharded_route`` — the shard_map program: per-shard scan over epochs,
  inner scan over blocks, ``lax.psum`` of the epoch's load delta.
* ``ref_sharded_route`` — the single-device oracle: the same epoch/block
  scans with the shard axis ``vmap``-ed and the psum replaced by a plain
  sum over shards.  Tests and single-device benches run this.

``routed_step_roofline`` lowers the compiled routed step and feeds
roofline/analysis.py: HLO flops / HBM bytes vs the memory-bandwidth bound,
plus per-epoch collective bytes (the psum traffic is ``n_workers`` f32 per
shard per epoch — tiny by design, which is why load-sync epochs scale).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map

from repro.core.estimation import W_SENTINEL
from repro.core.hashing import derive_seeds
from repro.kernels.route_core import hash_candidates, route_block

__all__ = [
    "SHARD_AXIS",
    "shard_grid",
    "sharded_route",
    "ref_sharded_route",
    "sharded_pkg_route",
    "sharded_w_route",
    "routed_step_roofline",
]

SHARD_AXIS = "data"  # the 1-D stream mesh axis (launch.mesh.make_stream_mesh)


def shard_grid(m: int, n_shards: int, sync_period: int, block: int) -> int:
    """Smallest per-shard length that fits m messages over n_shards on the
    (sync_period x block) epoch grid: every shard routes the same number of
    epochs, so the stream pads to n_shards * shard_grid(...) messages."""
    m_local = -(-m // n_shards)
    epoch = sync_period * block
    return max(-(-m_local // epoch), 1) * epoch


def _block_scan(loads0, cand_e, nc_e, *, n_workers: int, w_mode: bool,
                inv_cap=None):
    """One epoch on one shard: scan route_block over sync_period blocks from
    the epoch-start (globally synced) loads row.  Returns (epoch-end local
    loads (1, n_workers), choices (sync_period, block)).  inv_cap
    (1, n_workers) f32 makes every block's argmin capacity-normalized."""

    def blk(loads, inp):
        cand_b, nc_b = inp if nc_e is not None else (inp, None)
        choice, _, _, loads = route_block(
            cand_b, nc_b, loads, n_entities=n_workers, w_mode=w_mode,
            inv_cap=inv_cap,
        )
        return loads, choice

    xs = cand_e if nc_e is None else (cand_e, nc_e)
    return lax.scan(blk, loads0, xs)


@functools.lru_cache(maxsize=None)
def _build_sharded(n_workers, d_max, n_shards, n_epochs, sync_period, block,
                   w_mode, has_nc, has_cap, has_w, mesh):
    """Jitted shard_map program for one static configuration."""

    def shard_fn(keys_l, nc_l, seeds, icap, w_s):
        # keys_l (m_local,) — this shard's contiguous sub-stream; icap
        # (1, n_workers) replicated reciprocal capacities or None; w_s (1,)
        # this shard's load-sync delta weight or None.
        cand = hash_candidates(keys_l, seeds, n_workers)
        cand = cand.reshape(n_epochs, sync_period, block, d_max)
        nc = None if nc_l is None else nc_l.reshape(n_epochs, sync_period, block)

        def epoch(loads_g, inp):
            cand_e, nc_e = inp if nc is not None else (inp, None)
            loads_end, choices = _block_scan(
                loads_g, cand_e, nc_e, n_workers=n_workers, w_mode=w_mode,
                inv_cap=icap,
            )
            # load-sync: every shard contributes its epoch delta; the synced
            # row is the exact global histogram at the epoch boundary.  With
            # shard weights each delta is scaled BEFORE the psum (the
            # PR-8-follow-up capacity weighting); w == 1 is bit-exact to the
            # unweighted sync.
            delta = loads_end - loads_g
            if w_s is not None:
                delta = w_s.reshape(1, 1) * delta
            delta = lax.psum(delta, SHARD_AXIS)
            return loads_g + delta, choices

        loads0 = jnp.zeros((1, n_workers), jnp.float32)
        xs = cand if nc is None else (cand, nc)
        loads_f, assign = lax.scan(epoch, loads0, xs)
        return assign.reshape(-1), loads_f.reshape(n_workers)

    def fn(*a):
        it = iter(a)
        keys_l = next(it)
        nc_l = next(it) if has_nc else None
        seeds = next(it)
        icap = next(it) if has_cap else None
        w_s = next(it) if has_w else None
        return shard_fn(keys_l, nc_l, seeds, icap, w_s)

    # specs live in parallel.sharding next to the model-sharding plans
    # (lazy import: sharding pulls in the model registry)
    from repro.parallel.sharding import stream_shard_specs

    in_specs, out_specs = stream_shard_specs(
        has_ncand=has_nc, has_cap=has_cap, has_weights=has_w
    )
    mapped = shard_map(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False,
    )
    return jax.jit(mapped)


@functools.lru_cache(maxsize=None)
def _build_ref(n_workers, d_max, n_shards, n_epochs, sync_period, block,
               w_mode, has_nc, has_cap, has_w):
    """Jitted single-device oracle: vmap over the shard axis, psum -> sum."""

    def ref_fn(keys, nc_all, seeds, icap, w):
        cand = hash_candidates(keys, seeds, n_workers)
        cand = cand.reshape(n_shards, n_epochs, sync_period, block, d_max)
        cand = cand.swapaxes(0, 1)  # epoch-major for the outer scan
        nc = (
            None if nc_all is None
            else nc_all.reshape(n_shards, n_epochs, sync_period, block).swapaxes(0, 1)
        )

        def epoch(loads_g, inp):
            cand_e, nc_e = inp if nc is not None else (inp, None)

            def per_shard(c_s, n_s=None):
                return _block_scan(
                    loads_g, c_s, n_s, n_workers=n_workers, w_mode=w_mode,
                    inv_cap=icap,
                )

            if nc_e is None:
                loads_end, choices = jax.vmap(per_shard)(cand_e)
            else:
                loads_end, choices = jax.vmap(per_shard)(cand_e, nc_e)
            deltas = loads_end - loads_g  # (n_shards, 1, n_workers)
            if w is not None:
                deltas = w[:, None, None] * deltas
            return loads_g + deltas.sum(axis=0), choices

        loads0 = jnp.zeros((1, n_workers), jnp.float32)
        xs = cand if nc is None else (cand, nc)
        loads_f, assign = lax.scan(epoch, loads0, xs)
        # (n_epochs, n_shards, sync, block) -> shard-major stream order
        return assign.swapaxes(0, 1).reshape(-1), loads_f.reshape(n_workers)

    def fn(*a):
        it = iter(a)
        keys = next(it)
        nc_all = next(it) if has_nc else None
        seeds = next(it)
        icap = next(it) if has_cap else None
        w = next(it) if has_w else None
        return ref_fn(keys, nc_all, seeds, icap, w)

    return jax.jit(fn)


def _check_shapes(N: int, n_shards: int, sync_period: int, block: int) -> int:
    epoch = sync_period * block
    if n_shards < 1 or sync_period < 1:
        raise ValueError(f"n_shards/sync_period must be >= 1, got "
                         f"{n_shards}/{sync_period}")
    if N % (n_shards * epoch):
        raise ValueError(
            f"N={N} must divide by n_shards*sync_period*block = "
            f"{n_shards}*{sync_period}*{block} (pad with shard_grid)"
        )
    return N // (n_shards * epoch)  # n_epochs


def sharded_route(
    keys: jnp.ndarray,
    n_cand: Optional[jnp.ndarray],
    n_workers: int,
    *,
    d_max: int = 2,
    seed: int = 0,
    n_shards: int = 1,
    sync_period: int = 1,
    block: int = 128,
    w_mode: bool = False,
    mesh=None,
    capacities: Optional[jnp.ndarray] = None,
    shard_weights: Optional[jnp.ndarray] = None,
):
    """Route keys (N,) over an n_shards-device ("data",) mesh.

    Shard s routes the contiguous sub-stream keys[s*N/n_shards:(s+1)*...]
    with its own local loads row; every ``sync_period`` blocks the per-shard
    deltas are psum-ed (the load-sync epoch).  ``n_cand`` is the per-message
    candidate count (None: all d_max lanes live, plain PKG; W_SENTINEL
    entries take the global-argmin W path under ``w_mode=True`` — same
    contract as kernels.adaptive_route).  Returns (assign (N,) int32,
    final synced global loads (n_workers,) f32).

    ``capacities`` ((n_workers,) strictly positive) makes every shard's
    argmin capacity-normalized — each shard receives the same replicated
    reciprocal-capacity row the single-core kernels consume.
    ``shard_weights`` ((n_shards,) non-negative f32) scales each shard's
    load-sync delta before the psum, weighting the synced histogram by
    per-shard capacity; None or all-ones is bit-exact to the unweighted
    sync (integer counts in f32).

    ``n_shards=1, sync_period=1`` is bit-exact to the single-core Pallas
    routers (pkg_route / adaptive_route / w_route) over one chunk — they all
    call the same route_core.route_block.
    """
    N = keys.shape[0]
    n_epochs = _check_shapes(N, n_shards, sync_period, block)
    if mesh is None:
        from repro.launch.mesh import make_stream_mesh

        mesh = make_stream_mesh(n_shards)
    fn = _build_sharded(
        n_workers, d_max, n_shards, n_epochs, sync_period, block,
        bool(w_mode), n_cand is not None, capacities is not None,
        shard_weights is not None, mesh,
    )
    seeds = derive_seeds(seed, d_max)
    args = [keys.astype(jnp.int32)]
    if n_cand is not None:
        args.append(n_cand.astype(jnp.int32))
    args.append(seeds)
    if capacities is not None:
        args.append(
            1.0 / jnp.asarray(capacities, jnp.float32).reshape(1, n_workers)
        )
    if shard_weights is not None:
        args.append(jnp.asarray(shard_weights, jnp.float32).reshape(n_shards))
    return fn(*args)


def ref_sharded_route(
    keys: jnp.ndarray,
    n_cand: Optional[jnp.ndarray],
    n_workers: int,
    *,
    d_max: int = 2,
    seed: int = 0,
    n_shards: int = 1,
    sync_period: int = 1,
    block: int = 128,
    w_mode: bool = False,
    capacities: Optional[jnp.ndarray] = None,
    shard_weights: Optional[jnp.ndarray] = None,
):
    """Single-device oracle of sharded_route: identical epoch/block scans,
    shard axis vmap-ed, psum replaced by a sum over shards.  Bit-exact to
    the shard_map program (loads are integer counts in f32, so the reduction
    order cannot matter; weighted deltas sum in the same shard-major order
    the psum's ring reduction uses on a 1-D mesh), and the path
    single-device benches/tests run."""
    N = keys.shape[0]
    n_epochs = _check_shapes(N, n_shards, sync_period, block)
    fn = _build_ref(
        n_workers, d_max, n_shards, n_epochs, sync_period, block,
        bool(w_mode), n_cand is not None, capacities is not None,
        shard_weights is not None,
    )
    seeds = derive_seeds(seed, d_max)
    args = [keys.astype(jnp.int32)]
    if n_cand is not None:
        args.append(n_cand.astype(jnp.int32))
    args.append(seeds)
    if capacities is not None:
        args.append(
            1.0 / jnp.asarray(capacities, jnp.float32).reshape(1, n_workers)
        )
    if shard_weights is not None:
        args.append(jnp.asarray(shard_weights, jnp.float32).reshape(n_shards))
    return fn(*args)


def sharded_pkg_route(keys, n_workers: int, d: int = 2, **kw):
    """Plain PKG (fixed d candidates) on the sharded router."""
    return sharded_route(keys, None, n_workers, d_max=d, **kw)


def sharded_w_route(keys, is_head, n_workers: int, d: int = 2, **kw):
    """W-Choices on the sharded router: head keys (is_head != 0) go to the
    shard-locally least-loaded worker via the water-fill global argmin; tail
    keys take PKG's exact d-candidate step.  Same flag convention as
    kernels.adaptive_route.w_route."""
    flags = jnp.asarray(is_head).astype(jnp.int32)
    n_cand = jnp.where(flags != 0, jnp.int32(W_SENTINEL), jnp.int32(d))
    return sharded_route(keys, n_cand, n_workers, d_max=d, w_mode=True, **kw)


def routed_step_roofline(
    n_workers: int,
    *,
    n_shards: int = 1,
    sync_period: int = 1,
    n_epochs: int = 4,
    block: int = 128,
    d_max: int = 2,
    w_mode: bool = False,
    seed: int = 0,
    mesh=None,
    hw=None,
):
    """Compile the routed step and report its roofline terms + collective
    bytes (roofline/analysis.py): how far the compiled program sits from the
    memory-bandwidth bound, and what one load-sync epoch costs on the wire.

    Returns a dict with flops / hbm bytes / collective bytes per device,
    per-epoch collective bytes (the psum traffic), and the three-term
    roofline report.  Collective bytes are parsed from the post-SPMD HLO,
    so on a 1-shard mesh they are exactly zero — the sync is free when
    there is nobody to sync with.
    """
    from repro.roofline.analysis import HW, collective_bytes, roofline_report

    hw = hw or HW()
    if mesh is None:
        from repro.launch.mesh import make_stream_mesh

        mesh = make_stream_mesh(n_shards)
    N = n_shards * n_epochs * sync_period * block
    fn = _build_sharded(
        n_workers, d_max, n_shards, n_epochs, sync_period, block,
        bool(w_mode), True, False, False, mesh,
    )
    args = (
        jax.ShapeDtypeStruct((N,), jnp.int32),
        jax.ShapeDtypeStruct((N,), jnp.int32),
        jax.ShapeDtypeStruct((d_max,), jnp.uint32),
    )
    compiled = fn.lower(*args).compile()
    hlo = compiled.as_text()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax returns [dict]
        ca = ca[0] if ca else {}
    ca = ca or {}
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    # loads row is genuinely f32 (no bf16 wire correction applies).  The
    # load-sync all-reduce lives in the epoch loop's body computation, so the
    # static HLO parse counts it ONCE — that is the per-epoch wire cost; the
    # program executes it n_epochs times.
    coll = collective_bytes(hlo, bf16_wire=False)
    per_epoch = float(coll["total"])
    report = roofline_report(flops, hbm, per_epoch * n_epochs, hw=hw)
    return {
        "n_msgs": N,
        "n_shards": n_shards,
        "sync_period": sync_period,
        "n_epochs": n_epochs,
        "flops_per_device": flops,
        "hbm_bytes_per_device": hbm,
        "collective_bytes_per_epoch": per_epoch,
        "collective_bytes_per_device": per_epoch * n_epochs,
        "collective_counts": coll["counts"],
        "roofline": report,
    }
