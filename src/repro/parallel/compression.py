"""Gradient compression for cross-pod data parallelism (int8 + error feedback).

The cross-pod axis of a multi-pod mesh rides DCN-class links (an order of
magnitude slower than ICI), so the cross-pod gradient reduction is the
collective to compress.  Scheme: per-tensor int8 quantization with error
feedback (residual carried to the next step), reduced with all_gather(int8)
+ local dequant-sum — 4x fewer bytes on the wire than an fp32 ring
all-reduce for small pod counts (documented trade-off: all-gather scales
with n_pods; for n_pods <= 8 it wins).

Used inside shard_map (see train.loop.make_dp_train_step) so the collective
and its operand dtype are explicit in the lowered HLO — visible to the
roofline's collective-bytes parser.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["quantize_int8", "dequantize_int8", "compressed_psum_mean", "ef_init"]


def quantize_int8(x: jnp.ndarray):
    """x -> (int8 codes, fp32 scale). Symmetric per-tensor."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_init(tree):
    """Zero error-feedback residual matching a gradient tree."""
    return jax.tree_util.tree_map(lambda g: jnp.zeros(g.shape, jnp.float32), tree)


def compressed_psum_mean(grads, ef, axis_name: str):
    """Mean-reduce `grads` over `axis_name` with int8 codes on the wire.

    Must run inside shard_map.  Returns (mean_grads fp32, new_ef).
    """
    # jax.lax.axis_size only exists in newer jax; psum(1) is the portable form
    n = lax.psum(1, axis_name)

    def leaf(g, e):
        target = g.astype(jnp.float32) + e
        q, scale = quantize_int8(target)
        sent = dequantize_int8(q, scale)
        new_e = target - sent  # error feedback residual
        # the barrier pins the wire dtype: without it XLA hoists the f32
        # dequant convert above the gather and ships f32
        q = lax.optimization_barrier(q)
        qs = lax.all_gather(q, axis_name)  # (n, ...) int8 on the wire
        ss = lax.all_gather(scale, axis_name)  # (n,) fp32 (negligible)
        mean = jnp.tensordot(
            ss, qs.astype(jnp.float32), axes=((0,), (0,))
        ) / n
        return mean, new_e

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(ef)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    mean = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_ef = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return mean, new_ef
