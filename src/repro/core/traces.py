"""Real-trace ingestion: chunk-iterator readers for the paper's trace formats.

The paper's headline numbers come from Wikipedia pagecount and Twitter word
streams with millions of distinct keys.  Those traces are not redistributable,
but their *formats* are stable; this module reads them (or fixtures in the
same format, see tools/make_trace.py) as bounded-memory chunk iterators that
plug straight into parallel.chunked_driver — no array of the whole stream, no
key vocabulary, O(chunk) live memory per reader.

Key hashing
-----------
Raw string keys (page titles, words) are mapped to int32 ids with
``hash_raw_key`` — crc32 masked to 31 bits — WITHOUT materializing a
vocabulary: the id space is the hash range, so memory stays flat at any
number of distinct keys.  Downstream routing re-mixes ids through the
splitmix32 hash family (core.hashing), so candidate independence comes from
the router, not from this id assignment; an id collision (expected ~K^2/2^32
for K distinct keys) merely merges two keys' routing decisions, which is
conservative for the load-balance claims (merged keys are *harder* to
balance, never easier).

Formats
-------
* Wikipedia pagecounts (``read_wikipedia_pagecounts``): whitespace-separated
  ``project page_title count bytes`` lines, one per (project, page, hour);
  with ``expand_counts`` each line contributes ``count`` events, turning the
  hourly aggregate back into a visit stream as the paper uses it.
* Twitter-style key/timestamp (``read_kv_trace``): ``key<TAB>timestamp``
  lines, one event per line, timestamps ignored for routing.

Both readers accept plain or ``.gz`` files.  Synthetic generator-backed
streams share the same chunk-iterator contract via
``core.streams.stream_chunks``.
"""
from __future__ import annotations

import gzip
import zlib
from pathlib import Path
from typing import IO, Iterator, Union

import numpy as np

__all__ = [
    "hash_raw_key",
    "read_wikipedia_pagecounts",
    "read_kv_trace",
    "trace_chunks",
]

_ID_MASK = 0x7FFFFFFF  # 31 bits: non-negative int32 ids


def hash_raw_key(key: Union[str, bytes]) -> int:
    """Deterministic raw-key -> non-negative int32 id (no vocabulary)."""
    if isinstance(key, str):
        key = key.encode("utf-8", "surrogateescape")
    return zlib.crc32(key) & _ID_MASK


def _open_text(path: Union[str, Path]) -> IO[bytes]:
    path = Path(path)
    if path.suffix == ".gz":
        return gzip.open(path, "rb")
    return open(path, "rb")


def _chunked(events: Iterator[tuple[int, int]], chunk: int) -> Iterator[np.ndarray]:
    """Pack an iterator of (key_id, count) into int32 arrays of <= chunk.

    Counts are unrolled across chunk boundaries, so a single hot line with a
    huge count still costs O(chunk) memory — every yielded array except the
    final one has exactly `chunk` elements (what the driver's fixed-shape
    step wants)."""
    buf = np.empty(chunk, np.int32)
    fill = 0
    for kid, count in events:
        while count > 0:
            n = min(count, chunk - fill)
            buf[fill : fill + n] = kid
            fill += n
            count -= n
            if fill == chunk:
                yield buf.copy()
                fill = 0
    if fill:
        yield buf[:fill].copy()


def read_wikipedia_pagecounts(
    path: Union[str, Path],
    chunk: int = 65536,
    expand_counts: bool = True,
) -> Iterator[np.ndarray]:
    """Yield int32 key-id chunks from a Wikipedia pagecounts(-raw) file.

    Lines are ``project page_title count bytes``; the key is
    ``"project page_title"`` (titles never contain spaces in this format).
    With expand_counts=True (default) a line with count=c contributes c
    events — the visit stream the paper routes; with False each line is one
    event (distinct-page stream).  Malformed lines are skipped.
    """

    def events() -> Iterator[tuple[int, int]]:
        with _open_text(path) as f:
            for line in f:
                parts = line.split()
                if len(parts) < 3:
                    continue
                try:
                    c = int(parts[2])
                except ValueError:
                    continue
                if c <= 0:
                    continue
                yield hash_raw_key(parts[0] + b" " + parts[1]), (
                    c if expand_counts else 1
                )

    return _chunked(events(), chunk)


def read_kv_trace(path: Union[str, Path], chunk: int = 65536) -> Iterator[np.ndarray]:
    """Yield int32 key-id chunks from a Twitter-style ``key<TAB>ts`` file.

    One event per line; everything before the first tab is the key (so keys
    may contain spaces), the timestamp is ignored.  Blank lines are skipped.
    """

    def events() -> Iterator[tuple[int, int]]:
        with _open_text(path) as f:
            for line in f:
                key = line.split(b"\t", 1)[0].strip()
                if not key:
                    continue
                yield hash_raw_key(key), 1

    return _chunked(events(), chunk)


_READERS = {
    "wikipedia": read_wikipedia_pagecounts,
    "kv": read_kv_trace,
}


def trace_chunks(
    path: Union[str, Path], fmt: str, chunk: int = 65536
) -> Iterator[np.ndarray]:
    """Dispatch on format name ("wikipedia" | "kv") — the flag-friendly entry
    point benches and examples use (``--trace file --trace-format kv``)."""
    try:
        reader = _READERS[fmt]
    except KeyError:
        raise ValueError(f"unknown trace format {fmt!r}; choose from {sorted(_READERS)}")
    return reader(path, chunk=chunk)
