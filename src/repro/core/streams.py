"""Synthetic and trace-like key streams matching the paper's datasets (Table 1).

Real traces (Wikipedia page visits, Twitter words, cashtags, LiveJournal edges)
are not redistributable offline; we generate statistically-matched streams:
same key-space size, head probability p1, and drift/source-skew structure.
The paper's own synthetic workloads (Zipf ZF, lognormal LN1/LN2) are exact.

All generators are numpy-based (host-side data plane) and return int32 arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = [
    "zipf_probs",
    "zipf_stream",
    "lognormal_stream",
    "matched_trace_stream",
    "drift_stream",
    "abrupt_shift_stream",
    "multi_tenant_stream",
    "graph_edge_stream",
    "uniform_stream",
    "stream_chunks",
    "StreamSpec",
    "PAPER_DATASETS",
    "ScaleScenario",
    "SCALE_SCENARIOS",
    "DriftScenario",
    "DRIFT_SCENARIOS",
]


def zipf_probs(n_keys: int, z: float) -> np.ndarray:
    """Zipf pmf over ranks 1..n_keys with exponent z (paper eq. in SS6.1)."""
    ranks = np.arange(1, n_keys + 1, dtype=np.float64)
    w = ranks ** (-z)
    return w / w.sum()


# Uniform draws per slice of a sampling pass.  Bounded so a 1e8-event stream
# never materializes the float64 uniforms (or an int64 searchsorted result)
# for the whole stream at once; numpy's Generator fills sequentially, so any
# chunking of rng.random calls yields the same draw sequence — chunked
# sampling is bit-identical to one-shot for every chunk size.
_SAMPLE_CHUNK = 1 << 20


def _sample_from_probs(probs: np.ndarray, n_msgs: int, rng: np.random.Generator) -> np.ndarray:
    """Inverse-CDF sampling; keys are ranks ordered by decreasing probability.

    Samples in _SAMPLE_CHUNK-bounded slices straight into the int32 output:
    peak transient memory is O(_SAMPLE_CHUNK) on top of the result, instead
    of the 3x-of-stream float64 u + int64 indices + int32 astype copy the
    one-shot version allocated.
    """
    cdf = np.cumsum(probs)
    cdf[-1] = 1.0
    out = np.empty(n_msgs, dtype=np.int32)
    for lo in range(0, n_msgs, _SAMPLE_CHUNK):
        hi = min(lo + _SAMPLE_CHUNK, n_msgs)
        out[lo:hi] = np.searchsorted(cdf, rng.random(hi - lo), side="right")
    return out


def _sampled_chunks(probs, n_msgs: int, rng: np.random.Generator, chunk: int):
    """Yield _sample_from_probs(probs, n_msgs, rng) in `chunk`-sized pieces.

    Bit-identical to the one-shot call under concatenation (see
    _SAMPLE_CHUNK note), with O(chunk) live memory — the flat-RSS ingestion
    primitive behind stream_chunks()."""
    cdf = np.cumsum(probs)
    cdf[-1] = 1.0
    for lo in range(0, n_msgs, chunk):
        n = min(chunk, n_msgs - lo)
        yield np.searchsorted(cdf, rng.random(n), side="right").astype(np.int32)


def zipf_stream(n_msgs: int, n_keys: int, z: float, seed: int = 0) -> np.ndarray:
    """ZF workload: m iid samples from Zipf(z) over n_keys ranks."""
    rng = np.random.default_rng(seed)
    return _sample_from_probs(zipf_probs(n_keys, z), n_msgs, rng)


def lognormal_stream(
    n_msgs: int, n_keys: int, mu: float, sigma: float, seed: int = 0
) -> np.ndarray:
    """LN workload: key popularities drawn from lognormal(mu, sigma), then m samples.

    Paper parameters (from an Orkut analysis): LN1 mu=1.789, sigma=2.366 (K=16k);
    LN2 mu=2.245, sigma=1.133 (K=1.1k).
    """
    rng = np.random.default_rng(seed)
    pops = rng.lognormal(mean=mu, sigma=sigma, size=n_keys)
    pops = np.sort(pops)[::-1]
    probs = pops / pops.sum()
    return _sample_from_probs(probs, n_msgs, rng)


def _solve_zipf_for_p1(n_keys: int, p1: float) -> float:
    """Find z such that the Zipf head probability equals p1 (bisection)."""
    lo, hi = 0.0, 6.0
    for _ in range(80):
        mid = 0.5 * (lo + hi)
        if zipf_probs(n_keys, mid)[0] < p1:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def matched_trace_stream(
    n_msgs: int, n_keys: int, p1: float, seed: int = 0
) -> np.ndarray:
    """Trace emulation with a given key-space size and head probability p1.

    Used for WP (K=2.9M, p1=9.32%) and TW (K=31M, p1=2.67%) at reduced message
    counts; the imbalance *fraction* is scale-free in m for the regimes the
    paper studies (Thm 5.1: imbalance is Theta(m/n)).
    """
    z = _solve_zipf_for_p1(n_keys, p1)
    return zipf_stream(n_msgs, n_keys, z, seed=seed)


def drift_stream(
    n_msgs: int,
    n_keys: int,
    z: float,
    n_epochs: int = 8,
    rotate_top: int = 32,
    seed: int = 0,
    half_life: Optional[int] = None,
    slice_msgs: int = 512,
) -> np.ndarray:
    """Drifting skew: the identity of the hottest keys churns over time.

    Two modes, both Zipf(z) at every instant:

    - **Epoch rotation** (default, half_life=None): CT-style — emulates Fig. 3
      of the paper (weekly cashtag popularity shifts).  The rank->key mapping
      of the top `rotate_top` keys is re-permuted every n_msgs/n_epochs
      messages.
    - **Half-life churn** (half_life=H messages): continuous drift — every
      `slice_msgs` messages each of the top `rotate_top` rank identities is
      independently replaced with probability 1 - 2**(-slice_msgs/H), so after
      H messages about half the head set has turned over.  This is the regime
      where an offline (whole-stream) head estimate dilutes each hot key's
      average frequency below theta while its *instantaneous* frequency stays
      far above it — exactly what the online tracker exists for.
    """
    rng = np.random.default_rng(seed)
    probs = zipf_probs(n_keys, z)
    out = np.empty(n_msgs, dtype=np.int32)
    base = np.arange(n_keys, dtype=np.int32)
    rotate_top = min(rotate_top, n_keys)

    if half_life is None:
        per = max(n_msgs // n_epochs, 1)
        for e in range(n_epochs):
            mapping = base.copy()
            top = rng.permutation(n_keys)[:rotate_top].astype(np.int32)
            mapping[:rotate_top] = top
            lo = e * per
            if lo >= n_msgs:
                break
            hi = n_msgs if e == n_epochs - 1 else min((e + 1) * per, n_msgs)
            ranks = _sample_from_probs(probs, hi - lo, rng)
            out[lo:hi] = mapping[ranks]
        return out

    p_flip = 1.0 - 2.0 ** (-slice_msgs / float(half_life))
    mapping = base.copy()
    top = rng.permutation(n_keys)[:rotate_top].astype(np.int32)
    mapping[:rotate_top] = top
    in_top = set(int(k) for k in top)
    for lo in range(0, n_msgs, slice_msgs):
        hi = min(lo + slice_msgs, n_msgs)
        ranks = _sample_from_probs(probs, hi - lo, rng)
        out[lo:hi] = mapping[ranks]
        flips = np.flatnonzero(rng.random(rotate_top) < p_flip)
        for r in flips:
            in_top.discard(int(mapping[r]))
            k = int(rng.integers(n_keys))
            while k in in_top:  # keep head identities distinct
                k = int(rng.integers(n_keys))
            in_top.add(k)
            mapping[r] = k
    return out


def abrupt_shift_stream(
    n_msgs: int,
    n_keys: int,
    z: float,
    n_shifts: int = 3,
    seed: int = 0,
) -> np.ndarray:
    """Abrupt regime changes: the *entire* rank->key mapping is redrawn at
    each of `n_shifts` evenly-spaced shift points (n_shifts+1 regimes), so
    the old head set carries zero information about the new one — the
    hardest case for any estimator with memory.
    """
    rng = np.random.default_rng(seed)
    probs = zipf_probs(n_keys, z)
    out = np.empty(n_msgs, dtype=np.int32)
    n_regimes = n_shifts + 1
    per = max(n_msgs // n_regimes, 1)
    for e in range(n_regimes):
        mapping = rng.permutation(n_keys).astype(np.int32)
        lo = e * per
        if lo >= n_msgs:
            break
        hi = n_msgs if e == n_regimes - 1 else min((e + 1) * per, n_msgs)
        ranks = _sample_from_probs(probs, hi - lo, rng)
        out[lo:hi] = mapping[ranks]
    return out


def multi_tenant_stream(
    n_msgs: int,
    n_tenants: int = 4,
    n_keys: int = 2_000,
    z: float = 1.6,
    weights: Optional[np.ndarray] = None,
    half_life: Optional[int] = None,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Interleaved tenants over disjoint key ranges (tenant t owns
    [t*n_keys, (t+1)*n_keys)), each an independent Zipf(z) — optionally with
    per-tenant half-life churn.  `weights` skews traffic share across tenants
    (default uniform).  Returns (keys, tenant_id), both (n_msgs,) int32.
    """
    rng = np.random.default_rng(seed)
    w = np.full(n_tenants, 1.0 / n_tenants) if weights is None else (
        np.asarray(weights, np.float64) / np.sum(weights)
    )
    tenant = _sample_from_probs(w, n_msgs, rng)
    keys = np.empty(n_msgs, dtype=np.int32)
    for t in range(n_tenants):
        idx = np.flatnonzero(tenant == t)
        if half_life is None:
            sub = zipf_stream(len(idx), n_keys, z, seed=seed + 101 * (t + 1))
        else:
            sub = drift_stream(
                len(idx), n_keys, z, seed=seed + 101 * (t + 1),
                half_life=half_life,
            )
        keys[idx] = sub + t * n_keys
    return keys, tenant.astype(np.int32)


def graph_edge_stream(
    n_msgs: int,
    n_src_keys: int,
    n_dst_keys: int,
    z_out: float = 0.6,
    z_in: float = 0.55,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """LJ/SL-style edge stream: (src_key, dst_key) pairs with power-law degrees.

    Default exponents match LiveJournal's head mass (p1 ~ 0.3%, Table 1);
    heavier tails push past the p1 <= d/W balanceability bound of §5.

    The paper's Fig. 8 setup: source PEs are keyed (KG) by src vertex
    (projecting the out-degree skew onto sources) and messages to workers are
    keyed by dst vertex (in-degree skew onto workers).
    Returns (src_keys, dst_keys), both (n_msgs,) int32.
    """
    rng = np.random.default_rng(seed)
    src = _sample_from_probs(zipf_probs(n_src_keys, z_out), n_msgs, rng)
    dst = _sample_from_probs(zipf_probs(n_dst_keys, z_in), n_msgs, np.random.default_rng(seed + 1))
    return src, dst


def uniform_stream(n_msgs: int, n_keys: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, n_keys, size=n_msgs, dtype=np.int32)


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """A named workload (paper Table 1), scaled for offline simulation."""

    name: str
    n_msgs: int
    n_keys: int
    p1: Optional[float] = None  # matched-trace head probability
    z: Optional[float] = None  # zipf exponent
    mu: Optional[float] = None  # lognormal
    sigma: Optional[float] = None

    def generate(self, seed: int = 0, scale: float = 1.0) -> np.ndarray:
        m = max(int(self.n_msgs * scale), 1000)
        if self.p1 is not None:
            return matched_trace_stream(m, self.n_keys, self.p1, seed=seed)
        if self.z is not None:
            return zipf_stream(m, self.n_keys, self.z, seed=seed)
        assert self.mu is not None and self.sigma is not None
        return lognormal_stream(m, self.n_keys, self.mu, self.sigma, seed=seed)

    def stream_chunks(self, chunk: int, seed: int = 0, scale: float = 1.0):
        """Yield generate(seed, scale) in `chunk`-sized int32 pieces with
        O(n_keys + chunk) live memory — the pmf is computed once, then the
        stream is sampled lazily.  Concatenating the chunks is bit-identical
        to generate() for every chunk size (same rng draw order)."""
        m = max(int(self.n_msgs * scale), 1000)
        rng = np.random.default_rng(seed)
        if self.p1 is not None:
            probs = zipf_probs(self.n_keys, _solve_zipf_for_p1(self.n_keys, self.p1))
        elif self.z is not None:
            probs = zipf_probs(self.n_keys, self.z)
        else:
            assert self.mu is not None and self.sigma is not None
            pops = rng.lognormal(mean=self.mu, sigma=self.sigma, size=self.n_keys)
            pops = np.sort(pops)[::-1]
            probs = pops / pops.sum()
        yield from _sampled_chunks(probs, m, rng, chunk)


@dataclasses.dataclass(frozen=True)
class ScaleScenario:
    """Large-deployment regime of arXiv 1510.05714 (DESIGN.md SS3.3).

    Workers outnumber the head keys (W ∈ {50, 100}) under heavy skew
    (z ∈ [1.4, 2.0]), the regime where plain d=2 PKG stops balancing
    (p1 > d/W) and the adaptive D-/W-Choices partitioners take over.
    """

    name: str
    n_workers: int
    z: float
    n_msgs: int = 200_000
    n_keys: int = 10_000

    def generate(self, seed: int = 0, scale: float = 1.0) -> np.ndarray:
        m = max(int(self.n_msgs * scale), 1000)
        return zipf_stream(m, self.n_keys, self.z, seed=seed)

    def stream_chunks(self, chunk: int, seed: int = 0, scale: float = 1.0):
        """Flat-memory chunk iterator, bit-identical to generate() joined."""
        m = max(int(self.n_msgs * scale), 1000)
        rng = np.random.default_rng(seed)
        yield from _sampled_chunks(zipf_probs(self.n_keys, self.z), m, rng, chunk)

    def head_fraction(self) -> float:
        """p1 of the scenario's Zipf pmf — compare against d/W balanceability."""
        return float(zipf_probs(self.n_keys, self.z)[0])


SCALE_SCENARIOS = {
    s.name: s
    for s in (
        ScaleScenario(f"W{w}_z{z:.1f}", n_workers=w, z=z)
        for w in (50, 100)
        for z in (1.4, 1.6, 1.8, 2.0)
    )
}


@dataclasses.dataclass(frozen=True)
class DriftScenario:
    """A named drifting-head-set workload for the online-vs-offline benches.

    kind: "stationary" (plain Zipf), "churn" (half-life head churn),
    "abrupt" (full rank remaps), or "multi_tenant" (interleaved churned
    tenants).  half_life is in messages and scales with the stream so the
    *number of head turnovers* is scale-invariant.
    """

    name: str
    kind: str = "churn"
    n_workers: int = 100
    z: float = 1.8
    n_msgs: int = 100_000
    n_keys: int = 5_000
    half_life: Optional[int] = None  # fraction handled via half_life_frac
    half_life_frac: Optional[float] = None  # half-life as fraction of n_msgs
    rotate_top: int = 32
    n_shifts: int = 3
    n_tenants: int = 4

    def generate(self, seed: int = 0, scale: float = 1.0) -> np.ndarray:
        m = max(int(self.n_msgs * scale), 2_000)
        hl = self.half_life
        if hl is None and self.half_life_frac is not None:
            hl = max(int(m * self.half_life_frac), 1)
        if self.kind == "stationary":
            return zipf_stream(m, self.n_keys, self.z, seed=seed)
        if self.kind == "churn":
            return drift_stream(
                m, self.n_keys, self.z, rotate_top=self.rotate_top,
                seed=seed, half_life=hl,
            )
        if self.kind == "abrupt":
            return abrupt_shift_stream(
                m, self.n_keys, self.z, n_shifts=self.n_shifts, seed=seed
            )
        if self.kind == "multi_tenant":
            keys, _ = multi_tenant_stream(
                m, n_tenants=self.n_tenants,
                n_keys=self.n_keys // self.n_tenants, z=self.z,
                half_life=hl, seed=seed,
            )
            return keys
        raise ValueError(self.kind)

    def stream_chunks(self, chunk: int, seed: int = 0, scale: float = 1.0):
        """Chunk iterator over generate().  Drift streams carry stateful
        rank->key mappings, so this materializes the stream once and yields
        views — same ingestion API, but NOT flat-memory (use StreamSpec /
        ScaleScenario scenarios for the 1e8-event flat-RSS runs)."""
        keys = self.generate(seed=seed, scale=scale)
        for lo in range(0, len(keys), chunk):
            yield keys[lo : lo + chunk]


def stream_chunks(spec, chunk: int, seed: int = 0, scale: float = 1.0):
    """One ingestion path for benches and the chunked driver: yield the
    spec's stream as int32 chunks.  Dispatches to the spec's own
    stream_chunks (StreamSpec / ScaleScenario are flat-memory; DriftScenario
    materializes once); concatenation is bit-identical to spec.generate().
    """
    yield from spec.stream_chunks(chunk, seed=seed, scale=scale)


# Drift-rate sweep at W=100 (the PKG-hard regime) + structural variants; the
# churn half-lives are fractions of the stream so --scale preserves drift rate.
DRIFT_SCENARIOS = {
    s.name: s
    for s in (
        DriftScenario("stationary", kind="stationary"),
        DriftScenario("churn_hl32", kind="churn", half_life_frac=1 / 32),
        DriftScenario("churn_hl8", kind="churn", half_life_frac=1 / 8),
        DriftScenario("churn_hl2", kind="churn", half_life_frac=1 / 2),
        DriftScenario("abrupt_x3", kind="abrupt", n_shifts=3),
        DriftScenario("multi_tenant", kind="multi_tenant", half_life_frac=1 / 8),
    )
}


# Paper Table 1, messages scaled down by default (see DESIGN.md SS9.4);
# n_keys and p1 preserved exactly.
PAPER_DATASETS = {
    "WP": StreamSpec("WP", n_msgs=22_000_000, n_keys=2_900_000, p1=0.0932),
    "TW": StreamSpec("TW", n_msgs=1_200_000_000, n_keys=31_000_000, p1=0.0267),
    "CT": StreamSpec("CT", n_msgs=690_000, n_keys=2_900, p1=0.0329),
    "LN1": StreamSpec("LN1", n_msgs=10_000_000, n_keys=16_000, mu=1.789, sigma=2.366),
    "LN2": StreamSpec("LN2", n_msgs=10_000_000, n_keys=1_100, mu=2.245, sigma=1.133),
}
