"""Stateless hash family for PKG's d choices.

The paper uses 64-bit Murmur hashing; the algorithm only needs d independent,
uniform hash functions K -> [n].  On TPU we stay in 32-bit lanes (VPU-native)
and use a SplitMix32-style finalizer over (key ^ per-choice-seed), which passes
the avalanche tests that matter for choice independence.  The hash family is
orthogonal to the algorithm (DESIGN.md SS2).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = [
    "splitmix32",
    "splitmix32_np",
    "hash_choices",
    "hash_choices_np",
    "derive_seeds",
    "derive_seeds_np",
]

_M1 = np.uint32(0x7FEB352D)
_M2 = np.uint32(0x846CA68B)
_GOLDEN = np.uint32(0x9E3779B9)


def splitmix32(x: jnp.ndarray) -> jnp.ndarray:
    """SplitMix32 finalizer. x must be uint32; full avalanche mixing."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * _M1
    x = x ^ (x >> 15)
    x = x * _M2
    x = x ^ (x >> 16)
    return x


def splitmix32_np(x: np.ndarray) -> np.ndarray:
    """Numpy twin of splitmix32, bit-identical (same uint32 ops, IEEE-free).

    The host-side routing policies (core.routing) hash per request with this,
    so the serving edge and the device partitioners draw candidates from the
    SAME hash family — one _h32 fork less to drift.
    """
    with np.errstate(over="ignore"):
        x = np.asarray(x).astype(np.uint32)
        x = x ^ (x >> np.uint32(16))
        x = x * _M1
        x = x ^ (x >> np.uint32(15))
        x = x * _M2
        x = x ^ (x >> np.uint32(16))
    return x


def derive_seeds_np(seed: int, d: int) -> np.ndarray:
    """d decorrelated per-choice seeds from one integer seed (numpy uint32)."""
    base = np.uint32((int(seed) * 0x9E3779B9 + 0x9E3779B9) & 0xFFFFFFFF)
    with np.errstate(over="ignore"):
        seeds = (np.arange(1, d + 1, dtype=np.uint32) * _GOLDEN) ^ base
        # one extra scramble round so consecutive seeds differ in high bits too
        s = seeds
        s = s ^ (s >> np.uint32(16))
        s = s * _M1
        s = s ^ (s >> np.uint32(15))
    return s


def derive_seeds(seed: int, d: int) -> jnp.ndarray:
    """d decorrelated per-choice seeds from one integer seed."""
    return jnp.asarray(derive_seeds_np(seed, d), dtype=jnp.uint32)


def hash_choices(keys: jnp.ndarray, n_workers: int, d: int, seed: int = 0) -> jnp.ndarray:
    """Map keys (...,) -> candidate workers (..., d), each in [0, n_workers).

    Uses independent mixing per choice; modulo bias is negligible for
    n_workers << 2**32 (worst case 100 workers -> bias < 3e-8).
    """
    seeds = derive_seeds(seed, d)  # (d,)
    k = keys.astype(jnp.uint32)[..., None]  # (..., 1)
    h = splitmix32(k ^ seeds)  # (..., d)
    return (h % jnp.uint32(n_workers)).astype(jnp.int32)


def hash_choices_np(
    keys, n_workers: int, d: int, seed: int = 0
) -> np.ndarray:
    """Numpy twin of hash_choices: bit-identical candidates, no device round
    trip.  This is what the per-request serving schedulers hash with, which is
    why a scheduler and a partitioner given the same (key, seed, d, n) see the
    same candidate replicas."""
    seeds = derive_seeds_np(seed, d)  # (d,)
    k = np.asarray(keys).astype(np.uint32)[..., None]  # (..., 1)
    h = splitmix32_np(k ^ seeds)  # (..., d)
    return (h % np.uint32(n_workers)).astype(np.int32)
