"""The paper's §4 applications on top of PKG.

Heavy hitters (§4.2): SPACESAVING summaries per worker, merged downstream.
The Berinde et al. bound makes the estimation error grow with the number of
merged summaries — W for shuffle grouping but only 2 for PKG (key splitting),
while KG gets single-summary error at the price of load imbalance.

Streaming naïve Bayes (§2, running example): per-(word,class) counters.
Counters are a monoid, so PKG's two partial counts per word merge into the
exact totals — same model as sequential, with balanced workers and ≤2×K
counter state.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

import numpy as np

__all__ = ["SpaceSaving", "distributed_heavy_hitters", "StreamingNaiveBayes"]


class SpaceSaving:
    """Metwally et al. SPACESAVING: top-k frequencies in O(capacity) space."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.counts: dict[int, int] = {}
        self.errors: dict[int, int] = {}

    def offer(self, key: int, weight: int = 1) -> None:
        c = self.counts
        if key in c:
            c[key] += weight
            return
        if len(c) < self.capacity:
            c[key] = weight
            self.errors[key] = 0
            return
        victim = min(c, key=c.get)  # type: ignore[arg-type]
        base = c.pop(victim)
        self.errors.pop(victim)
        c[key] = base + weight
        self.errors[key] = base

    def offer_many(self, keys: Iterable[int]) -> None:
        for k in keys:
            self.offer(int(k))

    def estimate(self, key: int) -> int:
        return self.counts.get(key, 0)

    def max_error(self) -> int:
        """Upper bound on any estimate's error (min counter when full)."""
        if len(self.counts) < self.capacity:
            return 0
        return min(self.counts.values())

    def merge(self, other: "SpaceSaving") -> "SpaceSaving":
        """Mergeable-summaries merge (Berinde et al.): sum estimates, keep top."""
        out = SpaceSaving(self.capacity)
        keys = set(self.counts) | set(other.counts)
        merged = {
            k: self.estimate(k) + other.estimate(k) for k in keys
        }
        err = {
            k: self.errors.get(k, self.max_error())
            + other.errors.get(k, other.max_error())
            for k in keys
        }
        top = sorted(merged, key=merged.get, reverse=True)[: self.capacity]  # type: ignore[arg-type]
        out.counts = {k: merged[k] for k in top}
        out.errors = {k: err[k] for k in top}
        return out

    def top_k(self, k: int) -> list[tuple[int, int]]:
        return sorted(self.counts.items(), key=lambda kv: -kv[1])[:k]


def distributed_heavy_hitters(
    keys: np.ndarray,
    assign: np.ndarray,
    n_workers: int,
    capacity: int,
    top: int = 20,
) -> tuple[list[tuple[int, int]], int, np.ndarray]:
    """Run per-worker SPACESAVING under a partitioning; merge; return
    (top-k list, summed max-error bound, per-worker message loads)."""
    workers = [SpaceSaving(capacity) for _ in range(n_workers)]
    order = np.argsort(assign, kind="stable")
    sorted_assign = assign[order]
    sorted_keys = keys[order]
    bounds = np.searchsorted(sorted_assign, np.arange(n_workers + 1))
    for w in range(n_workers):
        workers[w].offer_many(sorted_keys[bounds[w] : bounds[w + 1]])
    merged = workers[0]
    for w in workers[1:]:
        merged = merged.merge(w)
    err = sum(w.max_error() for w in workers)
    loads = np.bincount(assign, minlength=n_workers)
    return merged.top_k(top), err, loads


@dataclasses.dataclass
class StreamingNaiveBayes:
    """Multinomial NB over (word, class) counters — the paper's running example.

    Counters live on whichever workers the partitioner chose; `merge_counts`
    folds the ≤d partial counts per word into the exact totals (monoid).
    """

    n_classes: int
    alpha: float = 1.0

    def __post_init__(self):
        self.word_class: dict[tuple[int, int], int] = {}
        self.class_counts = np.zeros(self.n_classes, dtype=np.int64)

    def observe(self, words: np.ndarray, label: int) -> None:
        for w in words:
            key = (int(w), label)
            self.word_class[key] = self.word_class.get(key, 0) + 1
        self.class_counts[label] += len(words)

    def merge_counts(self, other: "StreamingNaiveBayes") -> None:
        for key, v in other.word_class.items():
            self.word_class[key] = self.word_class.get(key, 0) + v
        self.class_counts += other.class_counts

    def predict(self, words: np.ndarray, vocab_size: int) -> int:
        tot = self.class_counts.astype(np.float64)
        logp = np.log((tot + 1.0) / (tot.sum() + self.n_classes))
        denom = np.log(tot + self.alpha * vocab_size)
        for w in words:
            for c in range(self.n_classes):
                num = self.word_class.get((int(w), c), 0) + self.alpha
                logp[c] += np.log(num) - denom[c]
        return int(np.argmax(logp))

    def n_counters(self) -> int:
        return len(self.word_class)
