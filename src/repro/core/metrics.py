"""Imbalance metrics (paper SS2).

I(t) = max_i L_i(t) - avg_i L_i(t).
The headline number in Tables 2 / Figs 4-9 is the *fraction of average
imbalance*: mean over sampled checkpoints of I(t), normalized by the total
number of messages m.

Metrics operate on assignment arrays (m,) so they are partitioner-agnostic;
computed in numpy (host side, post-hoc over simulated streams).
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "loads_from_assignment",
    "imbalance",
    "imbalance_series",
    "avg_imbalance_fraction",
    "final_imbalance_fraction",
    "capacity_imbalance_fraction",
    "keys_per_worker",
    "disagreement",
    "tenant_imbalance_report",
]


def loads_from_assignment(assign: np.ndarray, n_workers: int,
                          weights: np.ndarray | None = None) -> np.ndarray:
    return np.bincount(assign, weights=weights, minlength=n_workers).astype(np.float64)


def imbalance(loads: np.ndarray) -> float:
    """I(t) = max - avg."""
    return float(loads.max() - loads.mean())


def imbalance_series(
    assign: np.ndarray, n_workers: int, n_checkpoints: int = 100
) -> tuple[np.ndarray, np.ndarray]:
    """I(t) sampled at n_checkpoints points; returns (ts, I(ts)).

    The first checkpoint is clamped to >= 1: with ``m < n_checkpoints`` the
    naive ``m // n_checkpoints`` start is 0, and the spurious I(0) = 0 sample
    would dilute every mean taken over the series (avg_imbalance_fraction,
    tenant_imbalance_report) for short streams and small tenants.
    """
    m = len(assign)
    if m == 0:
        return np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.float64)
    ts = np.unique(
        np.linspace(max(m // n_checkpoints, 1), m, n_checkpoints).astype(np.int64)
    )
    loads = np.zeros(n_workers, dtype=np.int64)
    out = np.empty(len(ts), dtype=np.float64)
    prev = 0
    for i, t in enumerate(ts):
        loads += np.bincount(assign[prev:t], minlength=n_workers)
        prev = t
        out[i] = loads.max() - loads.mean()
    return ts, out


def avg_imbalance_fraction(
    assign: np.ndarray, n_workers: int, n_checkpoints: int = 100
) -> float:
    """Mean_t I(t) / m -- the number reported in paper Table 2 / Fig 4."""
    m = len(assign)
    if m == 0:
        return float("nan")
    _, series = imbalance_series(assign, n_workers, n_checkpoints)
    return float(series.mean() / m)


def final_imbalance_fraction(assign: np.ndarray, n_workers: int) -> float:
    """I(m) / m."""
    return imbalance(loads_from_assignment(assign, n_workers)) / len(assign)


def capacity_imbalance_fraction(
    assign: np.ndarray, capacities: np.ndarray,
    weights: np.ndarray | None = None,
) -> float:
    """Relative capacity-normalized imbalance of the final assignment
    (arXiv 1705.09073): ``(max_i l_i/c_i - L/C) / (L/C)`` with
    ``L = sum(l)``, ``C = sum(c)`` — 0 when every worker holds work exactly
    proportional to its capacity, and identical to the unweighted relative
    imbalance ``(max - mean)/mean`` at uniform capacities."""
    cap = np.asarray(capacities, dtype=np.float64)
    loads = loads_from_assignment(assign, len(cap), weights=weights)
    avg = loads.sum() / cap.sum()
    if avg == 0:
        return 0.0
    return float(((loads / cap).max() - avg) / avg)


def keys_per_worker(keys: np.ndarray, assign: np.ndarray, n_workers: int) -> np.ndarray:
    """Distinct keys held per worker == memory footprint of stateful operators.

    KG gives sum == K; SG tends to W*K; PKG <= 2K (key splitting).
    """
    pairs = np.unique(np.stack([assign.astype(np.int64), keys.astype(np.int64)]), axis=1)
    return np.bincount(pairs[0], minlength=n_workers)


def disagreement(a: np.ndarray, b: np.ndarray) -> float:
    """Fraction of messages routed differently by two strategies (Fig 6)."""
    return float(np.mean(a != b))


def tenant_imbalance_report(
    assign: np.ndarray,
    tenants: np.ndarray,
    n_workers: int,
    slo: float = 0.05,
    n_checkpoints: int = 50,
) -> dict:
    """Per-tenant SLO accounting over a shared assignment (DESIGN.md §8).

    Each tenant's sub-stream (streams.multi_tenant_stream returns the tenant
    ids) is scored in isolation over sampled checkpoints: I(t)/t > slo means
    that at time t the tenant's most-loaded replica held more than ``slo``
    of the tenant's own traffic above fair share.  ``checkpoint_violations``
    counts such checkpoints; a tenant is ``violated`` when the MEAN of the
    same I(t)/t series breaks the SLO — the verdict and the per-checkpoint
    test share one normalization, so a tenant persistently above the SLO is
    always flagged.  ``avg_imbalance_fraction`` (the paper's Table-2 metric,
    mean_t I(t) / m — note the different normalization) is reported
    alongside for comparability with the partitioner benches.  Returns a
    JSON-serialisable dict: {"slo", "tenants": {tid: {...}},
    "tenants_violating", "checkpoint_violations"}.
    """
    assign = np.asarray(assign)
    tenants = np.asarray(tenants)
    if assign.shape != tenants.shape:
        raise ValueError(f"shape mismatch {assign.shape} vs {tenants.shape}")
    per_tenant: dict = {}
    n_violating = 0
    total_ckpt_violations = 0
    for t in np.unique(tenants):
        sub = assign[tenants == t]
        ts, series = imbalance_series(sub, n_workers, n_checkpoints)
        frac_series = series / np.maximum(ts, 1)
        ckpt_viol = int((frac_series > slo).sum())
        mean_frac = float(frac_series.mean())
        violated = bool(mean_frac > slo)
        per_tenant[int(t)] = {
            "n_msgs": int(len(sub)),
            "avg_imbalance_fraction": float(series.mean() / len(sub)),
            "mean_imbalance_fraction": mean_frac,
            "checkpoint_violations": ckpt_viol,
            "checkpoints": int(len(ts)),
            "violated": violated,
        }
        n_violating += violated
        total_ckpt_violations += ckpt_viol
    return {
        "slo": float(slo),
        "tenants": per_tenant,
        "tenants_violating": int(n_violating),
        "checkpoint_violations": int(total_ckpt_violations),
    }
