"""Unified routing-policy substrate (DESIGN.md §8).

A RoutingPolicy is the paper's algorithm written ONCE and consumed three ways:

1. **Batch** — ``route_batch(keys, costs) -> assignments`` routes a whole
   stream on the host (candidate hashing vectorized via hash_choices_np, the
   load-dependent greedy step a tight numpy loop).  This is what simulations
   and benchmarks call.
2. **Per-request** — ``decide(key, loads)`` is one routing decision over a
   LoadLedger snapshot.  serving.scheduler.PolicyScheduler wraps (policy,
   ledger) into the classic ``route/complete`` scheduler interface; driving a
   fresh adapter over a stream with no completions is bit-identical to
   ``route_batch`` on the same stream (the differential contract in
   tests/test_routing.py).
3. **Device** — the Pallas routers (kernels.adaptive_route.w_route /
   adaptive_route) are registered as batch-only device-backed policies, so a
   benchmark sweep can put the TPU path on the same axis as the host
   policies.

Load accounting lives in exactly one place: LoadLedger.  Policies never
mutate loads themselves — ``decide`` reads a loads vector; the caller
(route_batch's internal ledger, or the serving adapter's shared one) acquires
and releases.  Estimator state (the W-Choices SPACESAVING tracker, the
round-robin cursor) lives on the policy and is cleared by ``reset()``;
``route_batch`` always routes from a fresh state so repeated calls are
deterministic.

All candidates come from core.hashing's SplitMix32 family (hash_choices_np is
bit-identical to the device hash_choices), so the serving edge, the host
simulation and the kernels agree on the candidate replicas of every key.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.estimation import SpaceSavingTracker, head_threshold
from repro.core.hashing import derive_seeds_np, hash_choices_np, splitmix32_np


def _hash_key_np(key: int, seeds: np.ndarray, n_workers: int) -> np.ndarray:
    """Scalar fast path of hash_choices_np with precomputed per-choice seeds
    (bit-identical; ``seeds = derive_seeds_np(seed, d)``).  decide() runs
    once per request, so re-deriving the seed family there would dominate
    the serving adapter's hot path."""
    with np.errstate(over="ignore"):
        h = splitmix32_np(np.uint32(int(key) & 0xFFFFFFFF) ^ seeds)
        return (h % np.uint32(n_workers)).astype(np.int32)

def _cap_alive(alive: Optional[np.ndarray],
               capacities: Optional[np.ndarray]) -> Optional[np.ndarray]:
    """Fold zero-capacity workers into the alive mask: a worker with c_i == 0
    can absorb no work, so every policy treats it exactly like a dead replica
    (same rehash-chain / skip / live-argmin failover paths)."""
    if capacities is None:
        return alive
    pos = capacities > 0
    if pos.all():
        return alive
    return pos if alive is None else (alive & pos)


def _cap_loads(loads: np.ndarray,
               capacities: Optional[np.ndarray]) -> np.ndarray:
    """Capacity-normalized loads ``load_i / c_i`` (inf where c_i == 0, so a
    zero-capacity worker never wins an argmin).  ``capacities=None`` returns
    ``loads`` unchanged — the pre-capacity fast path, bit-identical."""
    if capacities is None:
        return loads
    out = np.full(len(loads), np.inf, dtype=np.float64)
    np.divide(loads, capacities, out=out, where=capacities > 0)
    return out


def _check_capacities(n: int, capacities) -> Optional[np.ndarray]:
    """Validate and canonicalize a capacities vector (None passes through)."""
    if capacities is None:
        return None
    cap = np.asarray(capacities, dtype=np.float64).reshape(-1)
    if cap.shape != (n,):
        raise ValueError(f"capacities shape {cap.shape} != ({n},)")
    if not np.isfinite(cap).all() or (cap < 0).any():
        raise ValueError("capacities must be finite and >= 0")
    if not (cap > 0).any():
        raise ValueError("at least one capacity must be positive")
    return cap


__all__ = [
    "LoadLedger",
    "RoutingPolicy",
    "KGPolicy",
    "RoundRobinPolicy",
    "PoTCPolicy",
    "WChoicesPolicy",
    "DeviceWChoicesPolicy",
    "DeviceDChoicesPolicy",
    "ShardedWChoicesPolicy",
    "ROUTING_POLICIES",
    "DEFAULT_SCHEDULER",
    "host_policy_names",
    "scheduler_sweep_names",
    "make_policy",
]


class LoadLedger:
    """THE outstanding-work account: one float64 vector, acquire/release.

    Every consumer of a policy talks to loads through this class, so the
    "route adds exactly cost, complete releases it, never negative" contract
    is written once instead of per scheduler class.

    Two robustness extensions ride on the same account:

    * ``strict`` — release() normally clamps at zero, which silently masks
      double-``complete()`` bugs; strict mode raises on over-release instead
      (beyond a float-accumulation epsilon).  The serving simulator enables
      it, so its "ledger drains to exactly zero" invariant is enforced, not
      assumed.
    * a **live-replica mask** — ``alive`` is a bool vector; ``kill()`` /
      ``revive()`` flip it, and policies consult it through ``decide`` so a
      dead replica's keys are drained and redistributed (DESIGN.md §8).
      ``imbalance()`` is computed over live replicas only: a dead replica's
      zero load is capacity removed from the cluster, not spare headroom.
    * **per-worker capacities** (arXiv 1705.09073) — an optional weights
      vector ``c``; imbalance and every load comparison downstream work on
      the capacity-normalized loads ``load_i / c_i``, so a 4x-speed worker
      legitimately carries 4x the outstanding work.  ``capacities=None``
      keeps the uniform-cluster code path bit-identical to before; a
      zero-capacity worker is folded into the live mask (it behaves exactly
      like a dead replica).
    """

    __slots__ = ("loads", "alive", "strict", "capacities", "_n_dead", "_cap_mask")

    _EPS = 1e-6  # float accumulation tolerance for strict over-release

    def __init__(self, n_replicas: int, strict: bool = False, capacities=None):
        self.loads = np.zeros(n_replicas, dtype=np.float64)
        self.alive = np.ones(n_replicas, dtype=bool)
        self.strict = strict
        self._n_dead = 0
        self.capacities = None
        self._cap_mask = None
        if capacities is not None:
            self.set_capacities(capacities)

    @property
    def n(self) -> int:
        return len(self.loads)

    @property
    def any_dead(self) -> bool:
        return self._n_dead > 0

    def set_capacities(self, capacities) -> None:
        """Install (or clear, with None) the per-worker capacity vector."""
        cap = _check_capacities(self.n, capacities)
        self.capacities = cap
        if cap is None or (cap > 0).all():
            self._cap_mask = None
        else:
            self._cap_mask = cap > 0

    def normalized_loads(self) -> np.ndarray:
        """``load_i / c_i`` (inf at zero capacity); ``loads`` itself when no
        capacities are set."""
        return _cap_loads(self.loads, self.capacities)

    def live_mask(self) -> Optional[np.ndarray]:
        """The alive vector when any replica is dead, else None — the exact
        argument ``RoutingPolicy.decide`` takes (None keeps the all-alive
        fast path bit-identical to the pre-failover code).  Zero-capacity
        workers are merged in as dead."""
        if self._cap_mask is not None:
            return (self.alive & self._cap_mask) if self._n_dead else self._cap_mask
        return self.alive if self._n_dead else None

    def kill(self, replica: int) -> None:
        """Mark a replica dead; it stops receiving routes until revive()."""
        if self.alive[replica]:
            if self._n_dead == self.n - 1:
                raise ValueError("cannot kill the last live replica")
            self.alive[replica] = False
            self._n_dead += 1

    def revive(self, replica: int) -> None:
        if not self.alive[replica]:
            self.alive[replica] = True
            self._n_dead -= 1

    def acquire(self, replica: int, cost: float = 1.0) -> None:
        self.loads[replica] += cost

    def release(self, replica: int, cost: float = 1.0) -> None:
        """Completion event; clamps at zero unless ``strict``, which raises
        on over-release (the signature of a double-complete bug)."""
        rem = self.loads[replica] - cost
        if rem < -self._EPS and self.strict:
            raise ValueError(
                f"over-release on replica {replica}: outstanding "
                f"{self.loads[replica]:.6g} < released {cost:.6g} "
                "(double complete()?)"
            )
        self.loads[replica] = max(0.0, rem)

    def imbalance(self) -> float:
        """I(t) = max - avg of the current outstanding work (live replicas).

        With capacities set, both terms are capacity-normalized:
        ``max_i load_i/c_i - sum(loads)/sum(c)`` over live, positive-capacity
        replicas — the heterogeneous-cluster objective of arXiv 1705.09073
        (reduces exactly to max - mean at uniform capacity 1).
        """
        if self.capacities is None:
            live = self.loads[self.alive] if self._n_dead else self.loads
            return float(live.max() - live.mean())
        mask = self.alive if self._cap_mask is None else (self.alive & self._cap_mask)
        l, c = self.loads[mask], self.capacities[mask]
        return float((l / c).max() - l.sum() / c.sum())

    def imbalance_fraction(self) -> float:
        """I(t) normalized by the average (normalized) outstanding work per
        unit capacity — scale-invariant, 0 when idle."""
        if self.capacities is None:
            return self.imbalance() / max(float(self.loads.sum()), 1.0)
        mask = self.alive if self._cap_mask is None else (self.alive & self._cap_mask)
        total = float(self.loads[mask].sum() / self.capacities[mask].sum())
        return self.imbalance() / max(total, 1.0)


class RoutingPolicy:
    """Base policy: stateful estimator + pure decision over a loads vector.

    Subclasses implement ``decide`` (and usually override ``route_batch`` to
    hoist candidate hashing out of the loop).  ``reset()`` clears estimator
    state; ``route_batch`` calls it first, so a batch call always routes the
    stream from scratch.
    """

    name = "base"
    per_request = True  # False for device-backed batch-only policies

    def __init__(self, n_replicas: int, d: int = 2, seed: int = 0):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        self.n = n_replicas
        self.d = min(d, n_replicas)
        self.seed = seed

    def reset(self) -> None:
        """Clear estimator state (tracker, cursors); loads live elsewhere."""

    def decide(self, key: int, loads: np.ndarray,
               alive: Optional[np.ndarray] = None,
               capacities: Optional[np.ndarray] = None) -> int:
        """One routing decision over a loads vector.

        ``alive`` is the live-replica mask (None == everyone up, the fast
        path — bit-identical to the pre-failover substrate).  With a mask,
        every policy must return a live replica: a dead replica's keys are
        redistributed by the policy's own mechanism (KG rehashes down a
        deterministic candidate chain, RR skips dead slots, PoTC/W-Choices
        restrict their least-loaded choice to live candidates and spill to
        the global live argmin when all d candidates are dead).

        ``capacities`` (arXiv 1705.09073) weights every load comparison by
        ``load_i / c_i``; zero-capacity workers are folded into ``alive``
        and take the same failover paths as dead replicas.  None keeps the
        uniform-cluster path bit-identical.
        """
        raise NotImplementedError

    @staticmethod
    def _live_argmin(loads: np.ndarray, alive: np.ndarray) -> int:
        """Least-loaded live replica (lowest index ties)."""
        return int(np.argmin(np.where(alive, loads, np.inf)))

    def _batch_costs(self, m: int, costs) -> np.ndarray:
        if costs is None:
            return np.ones(m, dtype=np.float64)
        costs = np.asarray(costs, dtype=np.float64)
        if costs.shape != (m,):
            raise ValueError(f"costs shape {costs.shape} != ({m},)")
        return costs

    def route_batch(self, keys, costs=None, capacities=None) -> np.ndarray:
        """Route a stream from a fresh state; the per-request reference.

        Default implementation is the literal decide/acquire loop; overrides
        must stay bit-identical to it (that IS the adapter contract).
        Overrides that hoist candidate hashing keep their fast path for
        ``capacities=None`` and defer here for the capacity-weighted case.
        """
        self.reset()
        keys = np.asarray(keys).reshape(-1)
        costs = self._batch_costs(len(keys), costs)
        ledger = LoadLedger(self.n, capacities=capacities)
        alive = ledger.live_mask()
        out = np.empty(len(keys), dtype=np.int32)
        for i, k in enumerate(keys):
            c = self.decide(int(k), ledger.loads, alive, ledger.capacities)
            ledger.acquire(c, costs[i])
            out[i] = c
        return out


class KGPolicy(RoutingPolicy):
    """Key grouping: sticky single-choice hashing (load-oblivious)."""

    name = "kg"

    # rehash-chain length for failover: P(all chain hops dead) with k of n
    # replicas down is (k/n)^FAILOVER_CHAIN before the lowest-index fallback
    FAILOVER_CHAIN = 8

    def __init__(self, n_replicas: int, d: int = 2, seed: int = 0):
        super().__init__(n_replicas, d=d, seed=seed)
        self._seeds = derive_seeds_np(seed, 1)
        self._chain_seeds = derive_seeds_np(seed, 1 + self.FAILOVER_CHAIN)

    def decide(self, key: int, loads: np.ndarray,
               alive: Optional[np.ndarray] = None,
               capacities: Optional[np.ndarray] = None) -> int:
        alive = _cap_alive(alive, capacities)
        r = int(_hash_key_np(key, self._seeds, self.n)[0])
        if alive is None or alive[r]:
            return r
        # failover: walk a deterministic rehash chain (same SplitMix32
        # family, extra seeds) so a dead replica's keys scatter across the
        # cluster instead of piling onto one neighbour; final fallback is
        # the lowest-index live replica.
        for r in _hash_key_np(key, self._chain_seeds[1:], self.n):
            if alive[r]:
                return int(r)
        return int(np.argmax(alive))

    def route_batch(self, keys, costs=None, capacities=None) -> np.ndarray:
        if capacities is not None and not (np.asarray(capacities) > 0).all():
            # zero-capacity workers must take the rehash chain: generic loop
            return super().route_batch(keys, costs, capacities)
        self.reset()
        keys = np.asarray(keys).reshape(-1)
        self._batch_costs(len(keys), costs)  # validate shape only
        return hash_choices_np(keys, self.n, d=1, seed=self.seed)[:, 0]


class RoundRobinPolicy(RoutingPolicy):
    """Shuffle grouping: cyclic, key- and load-oblivious.

    The seed is honored as a scrambled start offset, so replicated frontends
    with different seeds don't all hammer replica 0 in lockstep.
    """

    name = "rr"

    def __init__(self, n_replicas: int, d: int = 2, seed: int = 0):
        super().__init__(n_replicas, d=d, seed=seed)
        self._offset = int(splitmix32_np(np.uint32(seed & 0xFFFFFFFF))) % self.n
        self._step = 0

    def reset(self) -> None:
        self._step = 0

    def decide(self, key: int, loads: np.ndarray,
               alive: Optional[np.ndarray] = None,
               capacities: Optional[np.ndarray] = None) -> int:
        alive = _cap_alive(alive, capacities)
        c = (self._offset + self._step) % self.n
        if alive is not None:
            while not alive[c]:  # skip dead slots; cycle stays uniform
                self._step += 1
                c = (self._offset + self._step) % self.n
        self._step += 1
        return c

    def route_batch(self, keys, costs=None, capacities=None) -> np.ndarray:
        if capacities is not None and not (np.asarray(capacities) > 0).all():
            return super().route_batch(keys, costs, capacities)
        self.reset()
        keys = np.asarray(keys).reshape(-1)
        self._batch_costs(len(keys), costs)
        out = ((self._offset + np.arange(len(keys), dtype=np.int64)) % self.n)
        self._step = len(keys)
        return out.astype(np.int32)


class PoTCPolicy(RoutingPolicy):
    """PKG at the edge: d hash candidates, least-loaded wins (first-index
    ties), loads are whatever ledger the caller carries — local estimation
    when each frontend keeps its own."""

    name = "potc"

    def __init__(self, n_replicas: int, d: int = 2, seed: int = 0):
        super().__init__(n_replicas, d=d, seed=seed)
        self._seeds = derive_seeds_np(seed, self.d)

    def candidates(self, key: int) -> np.ndarray:
        return _hash_key_np(key, self._seeds, self.n)

    def decide(self, key: int, loads: np.ndarray,
               alive: Optional[np.ndarray] = None,
               capacities: Optional[np.ndarray] = None) -> int:
        alive = _cap_alive(alive, capacities)
        loads = _cap_loads(loads, capacities)
        c = self.candidates(key)
        if alive is None:
            return int(c[np.argmin(loads[c])])
        if not alive[c].any():
            # every candidate is dead: spill to the global live argmin (the
            # W-Choices move, borrowed as the failover redistribution step)
            return self._live_argmin(loads, alive)
        return int(c[np.argmin(np.where(alive[c], loads[c], np.inf))])

    def route_batch(self, keys, costs=None, capacities=None) -> np.ndarray:
        if capacities is not None:
            return super().route_batch(keys, costs, capacities)
        self.reset()
        keys = np.asarray(keys).reshape(-1)
        costs = self._batch_costs(len(keys), costs)
        cand = hash_choices_np(keys, self.n, d=self.d, seed=self.seed)
        loads = np.zeros(self.n, dtype=np.float64)
        out = np.empty(len(keys), dtype=np.int32)
        for i in range(len(keys)):
            c = cand[i]
            w = c[np.argmin(loads[c])]
            loads[w] += costs[i]
            out[i] = w
        return out


class WChoicesPolicy(PoTCPolicy):
    """W-Choices at the edge (arXiv 1510.05714): hot keys go anywhere.

    A SPACESAVING tracker flags keys whose estimated request fraction clears
    ``theta`` (default d/n — the balanceability limit of paper §5); hot keys
    route to the globally least-loaded replica, cold keys keep PoTC's exact
    step (and therefore its <= d replica fanout / prefix-cache affinity).
    """

    name = "w_choices"

    def __init__(self, n_replicas: int, d: int = 2, seed: int = 0,
                 capacity: int = 256, theta: Optional[float] = None,
                 min_count: int = 8):
        super().__init__(n_replicas, d=d, seed=seed)
        self.theta = head_threshold(n_replicas, self.d) if theta is None else theta
        self.capacity = capacity
        self.min_count = min_count
        self.tracker = SpaceSavingTracker(capacity)

    def reset(self) -> None:
        self.tracker = SpaceSavingTracker(self.capacity)

    def is_hot(self, key: int) -> bool:
        return self.tracker.is_head(key, self.theta, min_count=self.min_count)

    def decide(self, key: int, loads: np.ndarray,
               alive: Optional[np.ndarray] = None,
               capacities: Optional[np.ndarray] = None) -> int:
        self.tracker.offer(key)
        if self.is_hot(key):
            alive = _cap_alive(alive, capacities)
            loads = _cap_loads(loads, capacities)
            if alive is None:
                return int(np.argmin(loads))
            return self._live_argmin(loads, alive)
        return super().decide(key, loads, alive, capacities)

    def route_batch(self, keys, costs=None, capacities=None) -> np.ndarray:
        if capacities is not None:
            return RoutingPolicy.route_batch(self, keys, costs, capacities)
        self.reset()
        keys = np.asarray(keys).reshape(-1)
        costs = self._batch_costs(len(keys), costs)
        cand = hash_choices_np(keys, self.n, d=self.d, seed=self.seed)
        loads = np.zeros(self.n, dtype=np.float64)
        out = np.empty(len(keys), dtype=np.int32)
        for i in range(len(keys)):
            k = int(keys[i])
            self.tracker.offer(k)
            if self.is_hot(k):
                w = int(np.argmin(loads))
            else:
                c = cand[i]
                w = c[np.argmin(loads[c])]
            loads[w] += costs[i]
            out[i] = w
        return out


class _DevicePolicy(RoutingPolicy):
    """Batch-only policy backed by a Pallas router (unit-cost messages).

    The kernels account loads in integer message counts, so non-unit costs
    are rejected rather than silently dropped; per-request ``decide`` is not
    available — wrap the host WChoicesPolicy for the serving adapter and use
    these for device-batch sweeps.
    """

    per_request = False

    def __init__(self, n_replicas: int, d: int = 2, seed: int = 0,
                 capacity: int = 1024, theta: Optional[float] = None,
                 min_count: int = 8, block: int = 128,
                 interpret: Optional[bool] = None):
        super().__init__(n_replicas, d=d, seed=seed)
        self.capacity = capacity
        self.theta = theta
        self.min_count = min_count
        self.block = block
        self.interpret = interpret

    def decide(self, key: int, loads: np.ndarray,
               alive: Optional[np.ndarray] = None,
               capacities: Optional[np.ndarray] = None) -> int:
        raise NotImplementedError(
            f"{type(self).__name__} is device-backed and batch-only; "
            "use route_batch, or a host policy for per-request serving"
        )

    def _unit_costs(self, m: int, costs) -> None:
        costs = self._batch_costs(m, costs)
        if not np.all(costs == 1.0):
            raise ValueError(
                "device-backed policies route unit-cost messages only"
            )

    def _kernel_capacities(self, capacities) -> Optional[np.ndarray]:
        """Kernels normalize by a reciprocal-capacity row, so every capacity
        must be strictly positive (fold zero-capacity workers out before the
        device batch; host policies handle them via the alive mask)."""
        cap = _check_capacities(self.n, capacities)
        if cap is not None and (cap <= 0).any():
            raise ValueError(
                "device-backed policies need strictly positive capacities"
            )
        return cap


class DeviceWChoicesPolicy(_DevicePolicy):
    """W-Choices on the in-kernel global-argmin path (kernels w_route)."""

    name = "w_choices_kernel"

    def route_batch(self, keys, costs=None, capacities=None) -> np.ndarray:
        from repro.core.partitioners import w_choices_kernel_partition

        keys = np.asarray(keys).reshape(-1)
        self._unit_costs(len(keys), costs)
        return np.asarray(
            w_choices_kernel_partition(
                keys, self.n, d=self.d, seed=self.seed,
                theta=self.theta, capacity=self.capacity,
                min_count=self.min_count, block=self.block,
                capacities=self._kernel_capacities(capacities),
                interpret=self.interpret,
            )
        )


class DeviceDChoicesPolicy(_DevicePolicy):
    """D-Choices on the Pallas masked-prefix router: a thin wrapper over
    core.partitioners.d_choices_kernel_partition (which shares its
    SPACESAVING pre-pass and d(k) schedule with d_choices_partition)."""

    name = "d_choices_kernel"

    def __init__(self, n_replicas: int, d: int = 2, seed: int = 0,
                 d_max: int = 16, slack: float = 2.0, **kw):
        super().__init__(n_replicas, d=d, seed=seed, **kw)
        self.d_max = max(int(min(d_max, n_replicas)), self.d)
        self.slack = slack

    def route_batch(self, keys, costs=None, capacities=None) -> np.ndarray:
        from repro.core.partitioners import d_choices_kernel_partition

        keys = np.asarray(keys).reshape(-1)
        self._unit_costs(len(keys), costs)
        return np.asarray(
            d_choices_kernel_partition(
                keys, self.n, d=self.d, d_max=self.d_max, seed=self.seed,
                theta=self.theta, capacity=self.capacity, slack=self.slack,
                min_count=self.min_count, block=self.block,
                capacities=self._kernel_capacities(capacities),
                interpret=self.interpret,
            )
        )


class ShardedWChoicesPolicy(_DevicePolicy):
    """W-Choices on the multi-device sharded router (DESIGN.md §6.1): the
    stream splits over an ``n_shards`` ("data",) mesh, every shard routes
    against its own local loads row, and the per-shard load deltas psum
    every ``sync_period`` blocks.  ``n_shards=1, sync_period=1`` is
    bit-exact to DeviceWChoicesPolicy's single-core kernel path; the mesh
    is emulated (vmap + sum, bit-identical) when the host has fewer than
    n_shards devices, so the registered policy runs anywhere."""

    name = "w_choices_sharded"

    def __init__(self, n_replicas: int, d: int = 2, seed: int = 0,
                 n_shards: int = 1, sync_period: int = 1, **kw):
        super().__init__(n_replicas, d=d, seed=seed, **kw)
        self.n_shards = n_shards
        self.sync_period = sync_period

    def route_batch(self, keys, costs=None, capacities=None) -> np.ndarray:
        from repro.core.partitioners import w_choices_sharded_partition

        keys = np.asarray(keys).reshape(-1)
        self._unit_costs(len(keys), costs)
        return np.asarray(
            w_choices_sharded_partition(
                keys, self.n, d=self.d, seed=self.seed, theta=self.theta,
                capacity=self.capacity, min_count=self.min_count,
                n_shards=self.n_shards, sync_period=self.sync_period,
                block=self.block,
                capacities=self._kernel_capacities(capacities),
            )
        )


ROUTING_POLICIES = {
    p.name: p
    for p in (
        KGPolicy,
        RoundRobinPolicy,
        PoTCPolicy,
        WChoicesPolicy,
        DeviceWChoicesPolicy,
        DeviceDChoicesPolicy,
        ShardedWChoicesPolicy,
    )
}


DEFAULT_SCHEDULER = "w_choices"


def host_policy_names() -> tuple:
    """Registered per-request-capable policies, in registry order — THE list
    the serving demos and bench sweep iterate, so a newly registered host
    policy shows up everywhere without editing three files."""
    return tuple(n for n, c in ROUTING_POLICIES.items() if c.per_request)


def scheduler_sweep_names() -> tuple:
    """host_policy_names with the preferred default (DEFAULT_SCHEDULER)
    listed first — the display order the launcher and demo share."""
    return (DEFAULT_SCHEDULER,) + tuple(
        n for n in host_policy_names() if n != DEFAULT_SCHEDULER
    )


def make_policy(name: str, n_replicas: int, **kw) -> RoutingPolicy:
    """Instantiate a registered policy; kw pass through to its __init__."""
    try:
        cls = ROUTING_POLICIES[name]
    except KeyError:
        raise ValueError(
            f"unknown routing policy {name!r}; "
            f"registered: {sorted(ROUTING_POLICIES)}"
        ) from None
    return cls(n_replicas, **kw)
