"""Stream partitioners: the paper's five techniques plus the TPU-batched PKG.

Every partitioner maps a key stream (m,) int32 -> worker assignment (m,) int32.
All are implemented as JAX programs (lax.scan for the sequential processes) so
the same code runs in simulation, in the host data pipeline, and inside jitted
steps.  Static configuration (n_workers, d, ...) is passed through
functools.partial/jit static args.

Techniques (paper SS6.2 Q1; adaptive variants from arXiv 1510.05714):
  H / KG      hash_partition          single choice, H1(k) mod n
  SG          shuffle_partition       round-robin, ignores keys
  PoTC        potc_static_partition   two choices, first decision remembered
  On-Greedy   on_greedy_partition     new key -> globally least-loaded worker
  Off-Greedy  off_greedy_partition    offline LPT on sorted key frequencies
  PKG         pkg_partition           Greedy-d with key splitting (the paper)
  PKG (TPU)   pkg_partition_batched   vector-block greedy, stale-by-<V loads
  D-Choices   d_choices_partition     head keys get skew-adaptive d(k) choices
  D (TPU)     d_choices_kernel_partition  same, on the Pallas masked-prefix path
  W-Choices   w_choices_partition     head keys may go to ANY worker
  W (TPU)     w_choices_kernel_partition  same, on the Pallas global-argmin path
  *-sharded   pkg/d_choices/w_choices_sharded_partition  multi-device mesh,
              per-shard local loads + psum load-sync epochs (DESIGN.md §6.1)

The adaptive variants (DESIGN.md SS3.3) come in two flavours.  The *offline*
pair (d_choices_partition / w_choices_partition) runs a SPACESAVING pre-pass
to find the head keys, then a single masked greedy scan.  The *online* pair
(online_d_choices_partition / online_w_choices_partition) carries the
SPACESAVING summary inside the lax.scan itself — head detection, d(k) and the
theta test all update per element, no pre-pass, which is what a real DSPE can
actually run and what survives key drift (DESIGN.md SS3.3 "Online
estimation").  In every flavour tail keys keep PKG's exact d=2 behaviour
(identical candidates, identical tie-breaking).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.estimation import (
    OnlineSS,
    SpaceSavingTracker,
    adaptive_d_counts,
    head_test,
    head_threshold,
    online_ss_decay,
    online_ss_estimate,
    online_ss_init,
    online_ss_update,
)
from repro.core.hashing import hash_choices

__all__ = [
    "hash_partition",
    "shuffle_partition",
    "pkg_partition",
    "pkg_partition_batched",
    "potc_static_partition",
    "on_greedy_partition",
    "off_greedy_partition",
    "d_choices_partition",
    "d_choices_kernel_partition",
    "w_choices_partition",
    "w_choices_kernel_partition",
    "online_d_choices_partition",
    "online_w_choices_partition",
    "pkg_sharded_partition",
    "d_choices_sharded_partition",
    "w_choices_sharded_partition",
    "pkg_chunked_partition",
    "d_choices_chunked_partition",
    "w_choices_chunked_partition",
    "PARTITIONERS",
]


@functools.partial(jax.jit, static_argnames=("n_workers", "seed"))
def hash_partition(keys: jnp.ndarray, n_workers: int, seed: int = 0) -> jnp.ndarray:
    """Key grouping: single-choice hashing (the paper's baseline H)."""
    return hash_choices(keys, n_workers, d=1, seed=seed)[..., 0]


@functools.partial(jax.jit, static_argnames=("n_workers",))
def shuffle_partition(keys: jnp.ndarray, n_workers: int, offset: int = 0) -> jnp.ndarray:
    """Shuffle grouping: cyclic round-robin; imbalance <= 1 by construction."""
    m = keys.shape[0]
    return ((jnp.arange(m, dtype=jnp.int32) + offset) % n_workers).astype(jnp.int32)


def _host_inv_cap(capacities, n_workers: int):
    """Validated (n_workers,) f32 reciprocal-capacity vector, or None.

    The host partitioners' capacity normalization (arXiv 1705.09073): every
    load comparison becomes ``load * (1/c)`` in f32 — the SAME product the
    kernels form, so host/kernel differentials stay bit-exact (loads are
    integer counts < 2^24).  Strictly positive capacities required here;
    zero-capacity workers are a routing-policy concept, folded into the
    alive mask at the LoadLedger layer, not a partitioner one.
    """
    if capacities is None:
        return None
    cap = np.asarray(capacities, dtype=np.float32).reshape(-1)
    if cap.shape != (n_workers,):
        raise ValueError(f"capacities shape {cap.shape} != ({n_workers},)")
    if not (cap > 0).all():
        raise ValueError("partitioner capacities must be strictly positive")
    return jnp.asarray(1.0 / cap)


def _trace_inv_cap(capacities, n_workers: int):
    """The in-jit twin of _host_inv_cap (no host-side validation — the
    argument may be a tracer).  Division by a non-positive capacity yields
    inf/nan comparisons; jitted callers document the > 0 requirement."""
    if capacities is None:
        return None
    return 1.0 / jnp.asarray(capacities, jnp.float32).reshape(n_workers)


def _greedy_scan(cand: jnp.ndarray, n_workers: int,
                 weights: Optional[jnp.ndarray], inv_cap=None):
    """Sequential Greedy-d over candidate sets cand (m, d).

    inv_cap (n_workers,) f32 switches the argmin to capacity-normalized
    loads (loads stay integer counts; only the comparison rescales).
    """
    m = cand.shape[0]
    w = jnp.ones((m,), jnp.int32) if weights is None else weights.astype(jnp.int32)

    def step(loads, inp):
        c, wt = inp
        lc = loads[c]  # (d,) current candidate loads
        if inv_cap is not None:
            lc = lc.astype(jnp.float32) * inv_cap[c]
        choice = c[jnp.argmin(lc)]
        return loads.at[choice].add(wt), choice

    loads0 = jnp.zeros((n_workers,), jnp.int32)
    _, assign = lax.scan(step, loads0, (cand, w))
    return assign


@functools.partial(jax.jit, static_argnames=("n_workers", "d", "seed"))
def pkg_partition(
    keys: jnp.ndarray,
    n_workers: int,
    d: int = 2,
    seed: int = 0,
    weights: Optional[jnp.ndarray] = None,
    capacities: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """PARTIAL KEY GROUPING: Greedy-d with key splitting (paper SS3).

    Every message is routed to the least-loaded of its d hash candidates,
    using the loads generated by *this* stream (local estimation when the
    stream is one source's sub-stream).  `capacities` (optional strictly
    positive (n_workers,) weights) makes the argmin capacity-normalized:
    least ``load/c`` wins; None is the unweighted path, bit-identical to
    before, and uniform capacities reproduce it exactly.
    """
    cand = hash_choices(keys, n_workers, d=d, seed=seed)
    return _greedy_scan(cand, n_workers, weights,
                        inv_cap=_trace_inv_cap(capacities, n_workers))


@functools.partial(jax.jit, static_argnames=("n_workers", "d", "seed", "block"))
def pkg_partition_batched(
    keys: jnp.ndarray,
    n_workers: int,
    d: int = 2,
    seed: int = 0,
    block: int = 128,
    capacities: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """TPU-native PKG: vector-block greedy with intra-block-stale loads.

    Processes `block` keys per step: candidate loads are read once per block,
    choices for all lanes are made in parallel, then the load vector is
    updated with the block's choice histogram (one-hot matmul -> MXU).
    Equivalent to local load estimation with ceil(m/block) micro-sources
    (DESIGN.md SS2); fidelity vs the sequential scan is quantified in
    benchmarks/bench_batched_fidelity.py.  `capacities` (> 0) switches the
    lane argmin to capacity-normalized loads.
    """
    m = keys.shape[0]
    inv_cap = _trace_inv_cap(capacities, n_workers)
    nblk = -(-m // block)
    pad = nblk * block - m
    keys_p = jnp.pad(keys, (0, pad))
    valid = jnp.pad(jnp.ones((m,), jnp.int32), (0, pad))
    cand = hash_choices(keys_p, n_workers, d=d, seed=seed)  # (nblk*block, d)
    cand = cand.reshape(nblk, block, d)
    valid = valid.reshape(nblk, block)

    def step(loads, inp):
        c, v = inp  # (block, d), (block,)
        lc = loads[c]  # (block, d)
        if inv_cap is not None:
            lc = lc.astype(jnp.float32) * inv_cap[c]
        sel = jnp.argmin(lc, axis=-1)  # (block,)
        choice = jnp.take_along_axis(c, sel[:, None], axis=-1)[:, 0]
        onehot = (jax.nn.one_hot(choice, n_workers, dtype=jnp.int32) * v[:, None])
        return loads + onehot.sum(axis=0), choice

    loads0 = jnp.zeros((n_workers,), jnp.int32)
    _, assign = lax.scan(step, loads0, (cand, valid))
    return assign.reshape(-1)[:m]


@functools.partial(jax.jit, static_argnames=("n_workers", "n_keys", "d", "seed"))
def potc_static_partition(
    keys: jnp.ndarray, n_workers: int, n_keys: int, d: int = 2, seed: int = 0,
    capacities: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Static PoTC *without* key splitting (paper SS3.1): the first placement of
    each key is remembered in a routing table and reused for every repeat.

    Needs O(n_keys) state -- the very cost PKG exists to remove; simulated here
    as a baseline.  Keys must be in [0, n_keys).  `capacities` (> 0) makes the
    first-placement argmin capacity-normalized.
    """
    cand = hash_choices(keys, n_workers, d=d, seed=seed)
    inv_cap = _trace_inv_cap(capacities, n_workers)

    def step(state, c):
        loads, table = state
        k, cd = c
        prev = table[k]
        lc = loads[cd]
        if inv_cap is not None:
            lc = lc.astype(jnp.float32) * inv_cap[cd]
        fresh = cd[jnp.argmin(lc)]
        choice = jnp.where(prev >= 0, prev, fresh)
        return (loads.at[choice].add(1), table.at[k].set(choice)), choice

    loads0 = jnp.zeros((n_workers,), jnp.int32)
    table0 = jnp.full((n_keys,), -1, jnp.int32)
    _, assign = lax.scan(step, (loads0, table0), (keys, cand))
    return assign


@functools.partial(jax.jit, static_argnames=("n_workers", "n_keys"))
def on_greedy_partition(
    keys: jnp.ndarray, n_workers: int, n_keys: int,
    capacities: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """On-Greedy (paper SS6.2): a new key goes to the globally least-loaded
    worker; the choice is remembered.  Requires global load + routing table.
    `capacities` (> 0) makes the global argmin capacity-normalized."""
    inv_cap = _trace_inv_cap(capacities, n_workers)

    def step(state, k):
        loads, table = state
        prev = table[k]
        nl = loads if inv_cap is None else loads.astype(jnp.float32) * inv_cap
        fresh = jnp.argmin(nl).astype(jnp.int32)
        choice = jnp.where(prev >= 0, prev, fresh)
        return (loads.at[choice].add(1), table.at[k].set(choice)), choice

    loads0 = jnp.zeros((n_workers,), jnp.int32)
    table0 = jnp.full((n_keys,), -1, jnp.int32)
    _, assign = lax.scan(step, (loads0, table0), keys)
    return assign


@functools.partial(jax.jit, static_argnames=("n_workers", "n_keys"))
def off_greedy_partition(
    keys: jnp.ndarray, n_workers: int, n_keys: int,
    capacities: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Off-Greedy (paper SS6.2): offline LPT -- sort keys by total frequency,
    assign each key's whole mass to the least-loaded worker.  Unfair upper
    baseline: it sees the full key distribution in advance.  `capacities`
    (> 0) runs LPT on capacity-normalized loads."""
    counts = jnp.zeros((n_keys,), jnp.int32).at[keys].add(1)
    order = jnp.argsort(-counts)  # keys by decreasing frequency
    inv_cap = _trace_inv_cap(capacities, n_workers)

    def step(state, k):
        loads, key2w = state
        nl = loads if inv_cap is None else loads.astype(jnp.float32) * inv_cap
        choice = jnp.argmin(nl).astype(jnp.int32)
        return (loads.at[choice].add(counts[k]), key2w.at[k].set(choice)), None

    loads0 = jnp.zeros((n_workers,), jnp.int32)
    key2w0 = jnp.zeros((n_keys,), jnp.int32)
    (_, key2w), _ = lax.scan(step, (loads0, key2w0), order)
    return key2w[keys]


@functools.partial(jax.jit, static_argnames=("n_workers",))
def _masked_greedy_scan(
    cand: jnp.ndarray, n_cand: jnp.ndarray, n_workers: int, inv_cap=None
) -> jnp.ndarray:
    """Greedy over a variable per-message prefix of cand (m, d_max).

    Candidate j of message i participates iff j < n_cand[i]; the rest are
    masked to INT32_MAX so argmin (first-index tie-break) matches pkg's
    behaviour exactly whenever n_cand[i] == d.  With inv_cap the comparison
    runs in f32 on normalized loads, masked with the kernels' f32 sentinel.
    """
    d_max = cand.shape[1]
    col = jnp.arange(d_max, dtype=jnp.int32)
    sentinel = jnp.iinfo(jnp.int32).max

    def step(loads, inp):
        c, nc = inp
        if inv_cap is None:
            lc = jnp.where(col < nc, loads[c], sentinel)
        else:
            lc = jnp.where(
                col < nc, loads[c].astype(jnp.float32) * inv_cap[c],
                jnp.float32(1e30),
            )
        choice = c[jnp.argmin(lc)]
        return loads.at[choice].add(1), choice

    loads0 = jnp.zeros((n_workers,), jnp.int32)
    _, assign = lax.scan(step, loads0, (cand, n_cand))
    return assign


@functools.partial(jax.jit, static_argnames=("n_workers",))
def _any_worker_greedy_scan(
    cand: jnp.ndarray, is_head: jnp.ndarray, n_workers: int, inv_cap=None
) -> jnp.ndarray:
    """Greedy-d for tail messages; global least-loaded for head messages.
    inv_cap switches both argmins to capacity-normalized loads."""

    def step(loads, inp):
        c, h = inp
        nl = loads if inv_cap is None else loads.astype(jnp.float32) * inv_cap
        tail_choice = c[jnp.argmin(nl[c])]
        head_choice = jnp.argmin(nl).astype(jnp.int32)
        choice = jnp.where(h, head_choice, tail_choice)
        return loads.at[choice].add(1), choice

    loads0 = jnp.zeros((n_workers,), jnp.int32)
    _, assign = lax.scan(step, loads0, (cand, is_head))
    return assign


def _head_lookup(
    keys: np.ndarray, head_ids: np.ndarray, head_vals: np.ndarray, default
) -> np.ndarray:
    """Map keys -> head_vals for head keys, `default` elsewhere (sorted ids)."""
    if len(head_ids) == 0:
        return np.full(len(keys), default, np.int32)
    idx = np.searchsorted(head_ids, keys)
    idx_c = np.clip(idx, 0, len(head_ids) - 1)
    hit = head_ids[idx_c] == keys
    return np.where(hit, head_vals[idx_c], default).astype(np.int32)


def _head_flags(
    keys_np: np.ndarray,
    n_workers: int,
    d: int,
    theta: Optional[float],
    capacity: int,
    min_count: int,
) -> np.ndarray:
    """THE offline W-Choices head set: SPACESAVING pre-pass + canonical
    head_counts, as per-message 0/1 flags (m,) int32.  Both W-Choices
    partitioners (sequential scan and Pallas kernel) share this one
    computation — their block=1 bit-exactness contract depends on the head
    sets being identical, so the pre-pass must not fork."""
    theta = head_threshold(n_workers, d) if theta is None else theta
    tracker = SpaceSavingTracker(capacity)
    tracker.update(keys_np)
    head_ids, _, _ = tracker.head_counts(theta, min_count)
    return _head_lookup(
        keys_np.astype(np.int64), head_ids, np.ones(len(head_ids), np.int32), 0
    )


def _adaptive_n_cand(
    keys_np: np.ndarray,
    n_workers: int,
    d: int,
    d_max: int,
    theta: Optional[float],
    capacity: int,
    slack: float,
    min_count: int,
) -> np.ndarray:
    """THE offline D-Choices pre-pass: SPACESAVING + canonical head_counts +
    integer-exact d(k), as a per-message candidate-count vector (m,) int32
    (tail messages get d).  Both D-Choices partitioners (masked greedy scan
    and Pallas kernel) share this one computation — their block=1
    bit-exactness contract depends on identical d(k) schedules, so the
    pre-pass must not fork (the W analogue is _head_flags)."""
    theta = head_threshold(n_workers, d) if theta is None else theta
    tracker = SpaceSavingTracker(capacity)
    tracker.update(keys_np)
    head_ids, head_cnt, total = tracker.head_counts(theta, min_count)
    d_head = adaptive_d_counts(
        head_cnt, total, n_workers, d_base=d, d_max=d_max, slack=slack
    )
    return _head_lookup(keys_np.astype(np.int64), head_ids, d_head, d)


def d_choices_partition(
    keys,
    n_workers: int,
    d: int = 2,
    d_max: int = 16,
    seed: int = 0,
    theta: Optional[float] = None,
    capacity: int = 1024,
    slack: float = 2.0,
    min_count: int = 8,
    capacities=None,
) -> jnp.ndarray:
    """D-CHOICES (arXiv 1510.05714): skew-adaptive number of choices.

    Head keys (canonical head_test: frequency fraction >= theta — default
    d/W — with at least min_count observations) receive
    d(k) = clip(ceil(slack * p_k * W), d, d_max) hash candidates; tail keys
    keep PKG's exact d choices.  Frequencies come from a SPACESAVING pass
    over the stream (O(capacity) state; DESIGN.md SS3.3).  The head test and
    the integer-exact d(k) rule are shared with the online variant, which is
    what makes the frozen-carry differential bit-exact.  `capacities` (> 0)
    normalizes the masked argmin by 1/c.
    """
    keys_np = np.asarray(keys, dtype=np.int32)
    d_max = max(int(min(d_max, n_workers)), d)
    n_cand = _adaptive_n_cand(
        keys_np, n_workers, d, d_max, theta, capacity, slack, min_count
    )
    cand = hash_choices(jnp.asarray(keys_np), n_workers, d=d_max, seed=seed)
    return _masked_greedy_scan(cand, jnp.asarray(n_cand), n_workers,
                               inv_cap=_host_inv_cap(capacities, n_workers))


def d_choices_kernel_partition(
    keys,
    n_workers: int,
    d: int = 2,
    d_max: int = 16,
    seed: int = 0,
    theta: Optional[float] = None,
    capacity: int = 1024,
    slack: float = 2.0,
    min_count: int = 8,
    chunk: Optional[int] = None,
    block: int = 128,
    interpret: Optional[bool] = None,
    capacities=None,
) -> jnp.ndarray:
    """D-CHOICES on the Pallas masked-prefix router.

    Same SPACESAVING pre-pass and d(k) schedule as d_choices_partition
    (shared _adaptive_n_cand), routed by kernels/adaptive_route.py with
    data-dependent candidate counts.  Chunk/pad convention matches
    w_choices_kernel_partition: one chunk of vector blocks by default,
    padding appended as tail messages (n_cand = d), block=1 reproduces
    d_choices_partition bit-exactly — including under `capacities` (> 0),
    which the kernel consumes as a reciprocal-capacity row.
    """
    from repro.kernels.adaptive_route import adaptive_route  # kernels on core

    keys_np = np.asarray(keys, dtype=np.int32)
    d_max = max(int(min(d_max, n_workers)), d)
    _host_inv_cap(capacities, n_workers)  # validate shape/positivity
    n_cand = _adaptive_n_cand(
        keys_np, n_workers, d, d_max, theta, capacity, slack, min_count
    )
    m = len(keys_np)
    if chunk is None:
        chunk = max(-(-m // block) * block, block)
    pad = -m % chunk
    assign, _ = adaptive_route(
        jnp.asarray(np.pad(keys_np, (0, pad))),
        jnp.asarray(np.pad(n_cand, (0, pad), constant_values=d)),
        n_workers, d_max=d_max, seed=seed, chunk=chunk, block=block,
        interpret=interpret,
        capacities=None if capacities is None else jnp.asarray(
            np.asarray(capacities, np.float32)
        ),
    )
    return assign[:m]


def w_choices_partition(
    keys,
    n_workers: int,
    d: int = 2,
    seed: int = 0,
    theta: Optional[float] = None,
    capacity: int = 1024,
    min_count: int = 8,
    capacities=None,
) -> jnp.ndarray:
    """W-CHOICES (arXiv 1510.05714): head keys may go to ANY worker.

    Tail keys are routed exactly as PKG (same candidates, same ties); head
    keys (canonical head_test, as in d_choices_partition) go to the globally
    least-loaded worker, which restores near-perfect balance however extreme
    the skew (at the cost of up to W-way key splitting for the few head
    keys; DESIGN.md SS3.3).  `capacities` (> 0) normalizes both the tail and
    the global argmin by 1/c — the heterogeneous-cluster variant (arXiv
    1705.09073): a 4x worker soaks up 4x the head traffic.
    """
    keys_np = np.asarray(keys, dtype=np.int32)
    is_head = _head_flags(
        keys_np, n_workers, d, theta, capacity, min_count
    ).astype(bool)
    cand = hash_choices(jnp.asarray(keys_np), n_workers, d=d, seed=seed)
    return _any_worker_greedy_scan(cand, jnp.asarray(is_head), n_workers,
                                   inv_cap=_host_inv_cap(capacities, n_workers))


def w_choices_kernel_partition(
    keys,
    n_workers: int,
    d: int = 2,
    seed: int = 0,
    theta: Optional[float] = None,
    capacity: int = 1024,
    min_count: int = 8,
    chunk: Optional[int] = None,
    block: int = 128,
    interpret: Optional[bool] = None,
    capacities=None,
) -> jnp.ndarray:
    """W-CHOICES on the Pallas router: the in-kernel global-argmin path.

    Same SPACESAVING pre-pass and head set as w_choices_partition, but the
    routing runs in kernels/adaptive_route.py — head keys are flagged with
    estimation.W_SENTINEL and taken to the globally least-loaded worker by
    the kernel's masked lane reduction, tail keys keep PKG's exact d-candidate
    step.  Defaults to one chunk (a single local estimator) with vector blocks
    of `block` keys, so loads are stale by < block messages (DESIGN.md SS2);
    block=1 reproduces w_choices_partition bit-exactly — including under
    `capacities` (> 0), which weights the tail argmin and the head water-fill
    by 1/c.  The stream is padded to the chunk grid with tail messages;
    padding is appended, so real assignments are unaffected.
    """
    from repro.kernels.adaptive_route import w_route  # kernels layer on core

    keys_np = np.asarray(keys, dtype=np.int32)
    _host_inv_cap(capacities, n_workers)  # validate shape/positivity
    is_head = _head_flags(keys_np, n_workers, d, theta, capacity, min_count)
    m = len(keys_np)
    if chunk is None:
        chunk = max(-(-m // block) * block, block)
    pad = -m % chunk
    assign, _ = w_route(
        jnp.asarray(np.pad(keys_np, (0, pad))),
        jnp.asarray(np.pad(is_head, (0, pad))),
        n_workers, d=d, seed=seed, chunk=chunk, block=block,
        interpret=interpret,
        capacities=None if capacities is None else jnp.asarray(
            np.asarray(capacities, np.float32)
        ),
    )
    return assign[:m]


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_workers", "d", "theta", "slack", "min_count", "decay_period",
        "any_worker", "update_tracker",
    ),
)
def _online_adaptive_scan(
    cand: jnp.ndarray,
    keys: jnp.ndarray,
    init_state: OnlineSS,
    n_workers: int,
    d: int,
    theta: float,
    slack: float,
    min_count: int,
    decay_period: int,
    any_worker: bool,
    update_tracker: bool,
    inv_cap=None,
) -> jnp.ndarray:
    """Single fused scan: SPACESAVING carry + head test + greedy routing.

    Per element, in order: (optional) windowed decay, tracker update (the
    router accounts for the message it is about to route), head verdict from
    the updated summary, then the same greedy step as the offline variants —
    masked d(k)-prefix argmin (D mode) or global argmin for head keys (W
    mode).  Tail verdicts reproduce PKG's step bit-exactly.  inv_cap
    (n_workers,) f32 switches every argmin to capacity-normalized loads,
    with the kernels' f32 1e30 sentinel masking dead candidate lanes.
    """
    m, d_max = cand.shape
    col = jnp.arange(d_max, dtype=jnp.int32)
    sentinel = jnp.iinfo(jnp.int32).max
    t_idx = jnp.arange(m, dtype=jnp.int32)

    def step(carry, inp):
        loads, state = carry
        c, k, t = inp
        if decay_period > 0:
            state = lax.cond(
                (t > 0) & (t % decay_period == 0),
                online_ss_decay, lambda s: s, state,
            )
        if update_tracker:
            state = online_ss_update(state, k)
        cnt = online_ss_estimate(state, k)
        is_head = head_test(cnt, state.total, theta, min_count)
        if any_worker:
            nl = loads if inv_cap is None else (
                loads.astype(jnp.float32) * inv_cap
            )
            tail_choice = c[jnp.argmin(nl[c])]
            head_choice = jnp.argmin(nl).astype(jnp.int32)
            choice = jnp.where(is_head, head_choice, tail_choice)
        else:
            dk = adaptive_d_counts(
                cnt, state.total, n_workers, d_base=d, d_max=d_max, slack=slack
            )
            nc = jnp.where(is_head, dk, d)
            if inv_cap is None:
                lc = jnp.where(col < nc, loads[c], sentinel)
            else:
                lc = jnp.where(
                    col < nc, loads[c].astype(jnp.float32) * inv_cap[c],
                    jnp.float32(1e30),
                )
            choice = c[jnp.argmin(lc)]
        return (loads.at[choice].add(1), state), choice

    loads0 = jnp.zeros((n_workers,), jnp.int32)
    _, assign = lax.scan(step, (loads0, init_state), (cand, keys, t_idx))
    return assign


def online_d_choices_partition(
    keys,
    n_workers: int,
    d: int = 2,
    d_max: int = 16,
    seed: int = 0,
    theta: Optional[float] = None,
    capacity: int = 256,
    slack: float = 2.0,
    min_count: int = 8,
    decay_period: int = 0,
    init_state: Optional[OnlineSS] = None,
    update_tracker: bool = True,
    capacities=None,
) -> jnp.ndarray:
    """Fully-online D-CHOICES: no pre-pass, head state lives in the scan carry.

    Identical routing rule to d_choices_partition, but the SPACESAVING summary
    is updated per element, so head keys are discovered (and, with
    decay_period > 0, forgotten) as the stream plays.  `min_count` suppresses
    head verdicts before a key has enough observations; `init_state` warm-
    starts the tracker (e.g. online_ss_from_tracker) and `update_tracker=False`
    freezes it, which reproduces the offline pre-pass variant bit-exactly
    (the differential contract in test_partitioner_invariants.py).
    `capacities` (> 0) normalizes the masked argmin by 1/c.
    """
    keys = jnp.asarray(keys, jnp.int32)
    d_max = max(int(min(d_max, n_workers)), d)
    theta = head_threshold(n_workers, d) if theta is None else float(theta)
    cand = hash_choices(keys, n_workers, d=d_max, seed=seed)
    state0 = online_ss_init(capacity) if init_state is None else init_state
    return _online_adaptive_scan(
        cand, keys, state0, n_workers=n_workers, d=d, theta=theta, slack=slack,
        min_count=min_count, decay_period=decay_period, any_worker=False,
        update_tracker=update_tracker,
        inv_cap=_host_inv_cap(capacities, n_workers),
    )


def online_w_choices_partition(
    keys,
    n_workers: int,
    d: int = 2,
    seed: int = 0,
    theta: Optional[float] = None,
    capacity: int = 256,
    min_count: int = 8,
    decay_period: int = 0,
    init_state: Optional[OnlineSS] = None,
    update_tracker: bool = True,
    capacities=None,
) -> jnp.ndarray:
    """Fully-online W-CHOICES: head keys go anywhere, detected in-scan.

    Tail messages take PKG's exact step; a message whose key currently clears
    theta in the carried summary goes to the globally least-loaded worker.
    See online_d_choices_partition for the tracker knobs.  `capacities` (> 0)
    normalizes both argmins by 1/c.
    """
    keys = jnp.asarray(keys, jnp.int32)
    theta = head_threshold(n_workers, d) if theta is None else float(theta)
    cand = hash_choices(keys, n_workers, d=d, seed=seed)
    state0 = online_ss_init(capacity) if init_state is None else init_state
    return _online_adaptive_scan(
        cand, keys, state0, n_workers=n_workers, d=d, theta=theta, slack=2.0,
        min_count=min_count, decay_period=decay_period, any_worker=True,
        update_tracker=update_tracker,
        inv_cap=_host_inv_cap(capacities, n_workers),
    )


# ---------------------------------------------------------------------------
# Multi-device sharded variants (parallel/sharded_router.py, DESIGN.md §6.1):
# the stream splits contiguously over an n_shards ("data",) mesh, each shard
# runs the shared block-greedy core on its own local loads row, and the
# per-shard load deltas psum every sync_period blocks (load-sync epochs).
# n_shards=1, sync_period=1 is bit-exact to the corresponding single-core
# kernel partitioner.
# ---------------------------------------------------------------------------


def _sharded_dispatch(
    keys_np: np.ndarray,
    n_cand_np: Optional[np.ndarray],
    pad_ncand: int,
    n_workers: int,
    *,
    d_max: int,
    seed: int,
    n_shards: int,
    sync_period: int,
    block: int,
    w_mode: bool,
    mesh,
    emulate: Optional[bool],
    capacities=None,
    shard_weights=None,
) -> jnp.ndarray:
    """Shared pad/route/trim plumbing for the *_sharded partitioners.

    Each shard's sub-stream pads AT ITS OWN END with tail messages (n_cand =
    pad_ncand), so real assignments within a shard are unaffected; pad
    messages do enter the synced histogram other shards see in late epochs —
    at most sync_period*block - 1 of them per shard, the same order as the
    staleness the epoch contract already grants.  ``emulate=None`` picks the
    shard_map program when the host has n_shards devices and the bit-exact
    single-device oracle (ref_sharded_route) otherwise, so the registered
    partitioners run anywhere.

    ``capacities`` (strictly positive (n_workers,)) makes every shard's
    argmin capacity-normalized; ``shard_weights`` (non-negative (n_shards,))
    scales each shard's load-sync psum delta — the per-shard capacity
    weighting of DESIGN.md §6.1's epoch sync.  Both default to the exact
    unweighted program.
    """
    from repro.parallel.sharded_router import (  # parallel layers on core
        ref_sharded_route,
        shard_grid,
        sharded_route,
    )

    m = len(keys_np)
    g = shard_grid(m, n_shards, sync_period, block)
    m_local = -(-m // n_shards)
    total = n_shards * g
    keys_p = np.zeros(total, np.int32)
    nc_p = (
        None if n_cand_np is None
        else np.full(total, pad_ncand, np.int32)
    )
    idx = np.empty(m, np.int64)
    pos = 0
    for s in range(n_shards):
        lo = s * m_local
        hi = min(m, lo + m_local)
        cnt = max(hi - lo, 0)
        keys_p[s * g:s * g + cnt] = keys_np[lo:hi]
        if nc_p is not None:
            nc_p[s * g:s * g + cnt] = n_cand_np[lo:hi]
        idx[pos:pos + cnt] = np.arange(s * g, s * g + cnt)
        pos += cnt
    _host_inv_cap(capacities, n_workers)  # validate shape/positivity
    cap = (
        None if capacities is None
        else jnp.asarray(np.asarray(capacities, np.float32))
    )
    sw = None
    if shard_weights is not None:
        sw_np = np.asarray(shard_weights, np.float32).reshape(-1)
        if sw_np.shape != (n_shards,):
            raise ValueError(
                f"shard_weights shape {sw_np.shape} != ({n_shards},)"
            )
        if not (np.isfinite(sw_np).all() and (sw_np >= 0).all()):
            raise ValueError("shard_weights must be finite and non-negative")
        sw = jnp.asarray(sw_np)
    if emulate is None:
        emulate = n_shards > jax.local_device_count()
    route = ref_sharded_route if emulate else sharded_route
    kw = {} if emulate else {"mesh": mesh}
    assign, _ = route(
        jnp.asarray(keys_p),
        None if nc_p is None else jnp.asarray(nc_p),
        n_workers, d_max=d_max, seed=seed, n_shards=n_shards,
        sync_period=sync_period, block=block, w_mode=w_mode,
        capacities=cap, shard_weights=sw, **kw,
    )
    return jnp.asarray(np.asarray(assign)[idx])


def pkg_sharded_partition(
    keys,
    n_workers: int,
    d: int = 2,
    seed: int = 0,
    n_shards: int = 1,
    sync_period: int = 1,
    block: int = 128,
    mesh=None,
    emulate: Optional[bool] = None,
    capacities=None,
    shard_weights=None,
) -> jnp.ndarray:
    """PKG on the multi-device sharded router (fixed d candidates)."""
    keys_np = np.asarray(keys, dtype=np.int32)
    return _sharded_dispatch(
        keys_np, None, d, n_workers, d_max=d, seed=seed, n_shards=n_shards,
        sync_period=sync_period, block=block, w_mode=False, mesh=mesh,
        emulate=emulate, capacities=capacities, shard_weights=shard_weights,
    )


def d_choices_sharded_partition(
    keys,
    n_workers: int,
    d: int = 2,
    d_max: int = 16,
    seed: int = 0,
    theta: Optional[float] = None,
    capacity: int = 1024,
    slack: float = 2.0,
    min_count: int = 8,
    n_shards: int = 1,
    sync_period: int = 1,
    block: int = 128,
    mesh=None,
    emulate: Optional[bool] = None,
    capacities=None,
    shard_weights=None,
) -> jnp.ndarray:
    """D-Choices on the sharded router: same offline SPACESAVING pre-pass and
    d(k) schedule as d_choices_kernel_partition (shared _adaptive_n_cand)."""
    keys_np = np.asarray(keys, dtype=np.int32)
    d_max = max(int(min(d_max, n_workers)), d)
    n_cand = _adaptive_n_cand(
        keys_np, n_workers, d, d_max, theta, capacity, slack, min_count
    )
    return _sharded_dispatch(
        keys_np, n_cand, d, n_workers, d_max=d_max, seed=seed,
        n_shards=n_shards, sync_period=sync_period, block=block,
        w_mode=False, mesh=mesh, emulate=emulate, capacities=capacities,
        shard_weights=shard_weights,
    )


def w_choices_sharded_partition(
    keys,
    n_workers: int,
    d: int = 2,
    seed: int = 0,
    theta: Optional[float] = None,
    capacity: int = 1024,
    min_count: int = 8,
    n_shards: int = 1,
    sync_period: int = 1,
    block: int = 128,
    mesh=None,
    emulate: Optional[bool] = None,
    capacities=None,
    shard_weights=None,
) -> jnp.ndarray:
    """W-Choices on the sharded router: same offline head set as
    w_choices_kernel_partition (shared _head_flags); head keys take the
    water-fill global argmin over each shard's local loads view."""
    from repro.core.estimation import W_SENTINEL

    keys_np = np.asarray(keys, dtype=np.int32)
    is_head = _head_flags(keys_np, n_workers, d, theta, capacity, min_count)
    n_cand = np.where(
        is_head != 0, np.int32(W_SENTINEL), np.int32(d)
    ).astype(np.int32)
    return _sharded_dispatch(
        keys_np, n_cand, d, n_workers, d_max=d, seed=seed, n_shards=n_shards,
        sync_period=sync_period, block=block, w_mode=True, mesh=mesh,
        emulate=emulate, capacities=capacities, shard_weights=shard_weights,
    )


# ---------------------------------------------------------------------------
# Chunked streaming variants (parallel/chunked_driver.py): the same route
# core driven chunk-at-a-time with a persistent (loads, Space-Saving) carry —
# flat memory in stream length, bit-exact to the one-shot kernels for every
# chunk size.  The adaptive variants share the online estimation machinery
# (online_ss_head_table emit, per-block stale tables as in
# estimation.online_head_tables) rather than any offline pre-pass: a chunked
# run must not require seeing the stream twice.
# ---------------------------------------------------------------------------


def pkg_chunked_partition(
    keys,
    n_workers: int,
    d: int = 2,
    seed: int = 0,
    chunk: int = 8192,
    block: int = 128,
    capacities=None,
) -> jnp.ndarray:
    """PKG routed chunk-at-a-time: bit-exact to pkg_route(chunk=N) at the
    same block size, with O(chunk) peak memory however long the stream.
    `keys` may be an array or an iterator of array chunks."""
    from repro.parallel.chunked_driver import ChunkedRouter  # parallel on core

    router = ChunkedRouter(
        n_workers, "pkg", d=d, chunk=chunk, block=block, seed=seed,
        capacities=capacities,
    )
    return jnp.asarray(router.route_stream(keys))


def d_choices_chunked_partition(
    keys,
    n_workers: int,
    d: int = 2,
    d_max: int = 8,
    seed: int = 0,
    theta: Optional[float] = None,
    capacity: int = 256,
    slack: float = 2.0,
    min_count: int = 8,
    decay_period: int = 0,
    chunk: int = 8192,
    block: int = 128,
    capacities=None,
) -> jnp.ndarray:
    """Online D-Choices routed chunk-at-a-time: the Space-Saving summary
    rides in the chunk-step carry and head tables are emitted per vector
    block (stale by <= block messages) — bit-exact to online_head_tables +
    adaptive_route_online over the whole stream, for every chunk size."""
    from repro.parallel.chunked_driver import ChunkedRouter  # parallel on core

    router = ChunkedRouter(
        n_workers, "d_choices", d=d, d_max=d_max, chunk=chunk, block=block,
        seed=seed, capacities=capacities, ss_capacity=capacity, theta=theta,
        slack=slack, min_count=min_count, decay_period=decay_period,
    )
    return jnp.asarray(router.route_stream(keys))


def w_choices_chunked_partition(
    keys,
    n_workers: int,
    d: int = 2,
    seed: int = 0,
    theta: Optional[float] = None,
    capacity: int = 256,
    min_count: int = 8,
    decay_period: int = 0,
    chunk: int = 8192,
    block: int = 128,
    capacities=None,
) -> jnp.ndarray:
    """Online W-Choices routed chunk-at-a-time: per-block any-worker head
    tables (W_SENTINEL) from the carried summary, head keys to the
    water-fill global argmin — bit-exact to the one-shot w-mode scan."""
    from repro.parallel.chunked_driver import ChunkedRouter  # parallel on core

    router = ChunkedRouter(
        n_workers, "w_choices", d=d, chunk=chunk, block=block, seed=seed,
        capacities=capacities, ss_capacity=capacity, theta=theta,
        min_count=min_count, decay_period=decay_period,
    )
    return jnp.asarray(router.route_stream(keys))


PARTITIONERS = {
    "kg": hash_partition,
    "sg": shuffle_partition,
    "pkg": pkg_partition,
    "pkg_batched": pkg_partition_batched,
    "potc": potc_static_partition,
    "on_greedy": on_greedy_partition,
    "off_greedy": off_greedy_partition,
    "d_choices": d_choices_partition,
    "d_choices_kernel": d_choices_kernel_partition,
    "w_choices": w_choices_partition,
    "w_choices_kernel": w_choices_kernel_partition,
    "d_choices_online": online_d_choices_partition,
    "w_choices_online": online_w_choices_partition,
    "pkg_sharded": pkg_sharded_partition,
    "d_choices_sharded": d_choices_sharded_partition,
    "w_choices_sharded": w_choices_sharded_partition,
    "pkg_chunked": pkg_chunked_partition,
    "d_choices_chunked": d_choices_chunked_partition,
    "w_choices_chunked": w_choices_chunked_partition,
}
