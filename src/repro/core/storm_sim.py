"""Queueing-model simulator for the paper's Storm deployment (Fig 10, Table 3).

The paper measures throughput/latency/memory of a top-k word-count topology on
a real Storm cluster.  Offline we model each worker as an M/D/1 queue with
deterministic per-message service time D (the paper's injected "CPU delay"):

  saturation throughput  T_sat = 1 / (D * max_i f_i)          [msgs/s]
  mean latency at rate r L(r)  = sum_i f_i * (D + rho_i*D / (2*(1-rho_i)))
                           with rho_i = r * f_i * D  (Pollaczek-Khinchine)

where f_i is worker i's share of messages under a given partitioner -- the
quantity PKG optimizes.  Memory is counted exactly (not modeled): the number
of live (worker, key) partial counters, flushed every aggregation period T
(PKG/SG) or held forever (KG), measured on the simulated stream.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["QueueModel", "aggregation_memory", "aggregation_message_overhead"]


@dataclasses.dataclass
class QueueModel:
    assign: np.ndarray  # (m,) worker per message
    n_workers: int
    service_delay_s: float  # D, per-message CPU time at a worker

    def __post_init__(self):
        loads = np.bincount(self.assign, minlength=self.n_workers).astype(np.float64)
        self.fractions = loads / loads.sum()

    @property
    def saturation_throughput(self) -> float:
        """Max sustainable msgs/s: the hottest worker saturates first."""
        return 1.0 / (self.service_delay_s * self.fractions.max())

    def mean_latency(self, rate: float) -> float:
        """Mean per-message latency (queueing + service) at input rate msgs/s.

        Returns inf when the hottest worker is over capacity.
        """
        rho = rate * self.fractions * self.service_delay_s
        if (rho >= 1.0).any():
            return float("inf")
        wait = rho * self.service_delay_s / (2.0 * (1.0 - rho))
        per_worker = self.service_delay_s + wait
        return float((self.fractions * per_worker).sum())


def aggregation_memory(
    keys: np.ndarray,
    assign: np.ndarray,
    n_workers: int,
    window: int,
) -> float:
    """Mean live partial counters per worker with aggregation every `window` msgs.

    PKG/SG flush partial (worker,key) counters downstream each period; KG holds
    one counter per key forever (window = len(keys) reproduces KG's footprint).
    """
    m = len(keys)
    window = max(1, min(window, m))
    totals = []
    for lo in range(0, m, window):
        hi = min(lo + window, m)
        pairs = np.stack(
            [assign[lo:hi].astype(np.int64), keys[lo:hi].astype(np.int64)]
        )
        totals.append(np.unique(pairs, axis=1).shape[1])
    return float(np.mean(totals) / n_workers)


def aggregation_message_overhead(
    keys: np.ndarray, assign: np.ndarray, n_workers: int, window: int
) -> float:
    """Extra downstream messages per input message due to periodic flushes."""
    m = len(keys)
    window = max(1, min(window, m))
    total = 0
    for lo in range(0, m, window):
        hi = min(lo + window, m)
        pairs = np.stack(
            [assign[lo:hi].astype(np.int64), keys[lo:hi].astype(np.int64)]
        )
        total += np.unique(pairs, axis=1).shape[1]
    return total / m
