"""Multi-source simulation with local load estimation (paper SS3.2, SS6.2 Q2).

A single lax.scan walks the stream in global arrival order, carrying
  local_est : (S, n)  per-source local load estimates
  global_ld : (n,)    true worker loads
Each message is routed by its source's *local* estimate (technique L), by the
true loads (G, the global oracle), or by local estimates that are periodically
reset to the true loads (LP, probing every probe_period messages).

Source assignment of messages is either round-robin shuffle (the default in
the paper) or key grouping on a secondary key (Fig 8's skewed-sources setup).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.hashing import hash_choices

__all__ = ["simulate_sources", "source_assignment", "local_imbalance_bound"]


def source_assignment(
    n_msgs: int,
    n_sources: int,
    source_keys: Optional[np.ndarray] = None,
    seed: int = 17,
) -> np.ndarray:
    """Message -> source map: shuffle (round-robin) or KG on source_keys."""
    if source_keys is None:
        return (np.arange(n_msgs, dtype=np.int64) % n_sources).astype(np.int32)
    h = np.asarray(
        hash_choices(jnp.asarray(source_keys, jnp.int32), n_sources, d=1, seed=seed)
    )[..., 0]
    return h.astype(np.int32)


@functools.partial(
    jax.jit,
    static_argnames=("n_workers", "n_sources", "d", "seed", "mode", "probe_period"),
)
def _simulate(
    keys: jnp.ndarray,
    sources: jnp.ndarray,
    n_workers: int,
    n_sources: int,
    d: int,
    seed: int,
    mode: str,
    probe_period: int,
) -> jnp.ndarray:
    cand = hash_choices(keys, n_workers, d=d, seed=seed)  # (m, d)
    m = keys.shape[0]
    t_idx = jnp.arange(m, dtype=jnp.int32)

    def step(state, inp):
        local_est, global_ld = state
        c, s, t = inp
        if mode == "probe":
            do_probe = (t % probe_period) == 0
            local_est = jnp.where(
                do_probe, jnp.broadcast_to(global_ld, local_est.shape), local_est
            )
        if mode == "global":
            lc = global_ld[c]
        else:
            lc = local_est[s, c]
        choice = c[jnp.argmin(lc)]
        local_est = local_est.at[s, choice].add(1)
        global_ld = global_ld.at[choice].add(1)
        return (local_est, global_ld), choice

    state0 = (
        jnp.zeros((n_sources, n_workers), jnp.int32),
        jnp.zeros((n_workers,), jnp.int32),
    )
    _, assign = lax.scan(step, state0, (cand, sources, t_idx))
    return assign


def simulate_sources(
    keys: np.ndarray,
    n_workers: int,
    n_sources: int = 5,
    d: int = 2,
    seed: int = 0,
    mode: str = "local",  # local (L) | global (G) | probe (LP)
    probe_period: int = 0,
    source_keys: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Run the S-source PKG simulation; returns the assignment (m,)."""
    assert mode in ("local", "global", "probe")
    src = source_assignment(len(keys), n_sources, source_keys)
    assign = _simulate(
        jnp.asarray(keys, jnp.int32),
        jnp.asarray(src, jnp.int32),
        n_workers=n_workers,
        n_sources=n_sources,
        d=d,
        seed=seed,
        mode=mode,
        probe_period=max(probe_period, 1),
    )
    return np.asarray(assign)


def local_imbalance_bound(
    keys: np.ndarray,
    assign: np.ndarray,
    sources: np.ndarray,
    n_workers: int,
    n_sources: int,
) -> tuple[float, float]:
    """Return (global imbalance, sum of per-source local imbalances).

    Paper SS3.2 theorem: I(t) <= sum_j I_hat_j(t).  Exposed for tests.
    """
    per = np.zeros((n_sources, n_workers), dtype=np.int64)
    np.add.at(per, (sources, assign), 1)
    global_ld = per.sum(axis=0)
    gi = global_ld.max() - global_ld.mean()
    li = (per.max(axis=1) - per.mean(axis=1)).sum()
    return float(gi), float(li)
