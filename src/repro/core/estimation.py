"""Load and frequency estimation (paper SS3.2, SS6.2 Q2; DESIGN.md SS3.3).

Two kinds of estimators live here:

1. Multi-source *load* simulation.  A single lax.scan walks the stream in
   global arrival order, carrying
     local_est : (S, n)  per-source local load estimates
     global_ld : (n,)    true worker loads
   Each message is routed by its source's *local* estimate (technique L), by
   the true loads (G, the global oracle), or by local estimates that are
   periodically reset to the true loads (LP, probing every probe_period
   messages).  Source assignment of messages is either round-robin shuffle
   (the default in the paper) or key grouping on a secondary key (Fig 8's
   skewed-sources setup).

2. Streaming *frequency* estimation for the adaptive multi-choice
   partitioners (arXiv 1510.05714).  SpaceSavingTracker identifies the head
   keys of the stream in O(capacity) space; head_threshold / adaptive_d
   encode the head/tail rule and the skew-adaptive choice count d(k)
   (DESIGN.md SS3.3).

3. The *online* estimator (DESIGN.md SS3.3 "Online estimation"): the same
   SPACESAVING summary as flat JAX arrays (OnlineSS) with pure per-element
   update/decay transitions, so the tracker rides inside a partitioner's
   lax.scan carry and head detection happens per message with no pre-pass.
   adaptive_d_counts is the integer-exact d(k) rule shared by the offline
   pre-pass and the scan so both paths make bit-identical decisions.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.applications import SpaceSaving
from repro.core.hashing import hash_choices

__all__ = [
    "simulate_sources",
    "source_assignment",
    "local_imbalance_bound",
    "W_SENTINEL",
    "SpaceSavingTracker",
    "head_test",
    "head_threshold",
    "adaptive_d",
    "adaptive_d_counts",
    "OnlineSS",
    "online_ss_init",
    "online_ss_update",
    "online_ss_decay",
    "online_ss_estimate",
    "online_ss_from_tracker",
    "online_ss_head_table",
    "online_head_tables",
]


# Candidate-count value flagging "this key may go to ANY worker" (W-Choices,
# arXiv 1510.05714) to the Pallas router and its oracle.  int32 max can never
# collide with a real d(k) — those are clipped to d_max <= n_workers — and a
# consumer that treats it as a plain count would mask nothing (every lane
# < W_SENTINEL participates), degrading to d_max choices instead of crashing.
W_SENTINEL = np.int32(np.iinfo(np.int32).max)


def source_assignment(
    n_msgs: int,
    n_sources: int,
    source_keys: Optional[np.ndarray] = None,
    seed: int = 17,
) -> np.ndarray:
    """Message -> source map: shuffle (round-robin) or KG on source_keys."""
    if source_keys is None:
        return (np.arange(n_msgs, dtype=np.int64) % n_sources).astype(np.int32)
    h = np.asarray(
        hash_choices(jnp.asarray(source_keys, jnp.int32), n_sources, d=1, seed=seed)
    )[..., 0]
    return h.astype(np.int32)


@functools.partial(
    jax.jit,
    static_argnames=("n_workers", "n_sources", "d", "seed", "mode", "probe_period"),
)
def _simulate(
    keys: jnp.ndarray,
    sources: jnp.ndarray,
    n_workers: int,
    n_sources: int,
    d: int,
    seed: int,
    mode: str,
    probe_period: int,
) -> jnp.ndarray:
    cand = hash_choices(keys, n_workers, d=d, seed=seed)  # (m, d)
    m = keys.shape[0]
    t_idx = jnp.arange(m, dtype=jnp.int32)

    def step(state, inp):
        local_est, global_ld = state
        c, s, t = inp
        if mode == "probe":
            do_probe = (t % probe_period) == 0
            local_est = jnp.where(
                do_probe, jnp.broadcast_to(global_ld, local_est.shape), local_est
            )
        if mode == "global":
            lc = global_ld[c]
        else:
            lc = local_est[s, c]
        choice = c[jnp.argmin(lc)]
        local_est = local_est.at[s, choice].add(1)
        global_ld = global_ld.at[choice].add(1)
        return (local_est, global_ld), choice

    state0 = (
        jnp.zeros((n_sources, n_workers), jnp.int32),
        jnp.zeros((n_workers,), jnp.int32),
    )
    _, assign = lax.scan(step, state0, (cand, sources, t_idx))
    return assign


def simulate_sources(
    keys: np.ndarray,
    n_workers: int,
    n_sources: int = 5,
    d: int = 2,
    seed: int = 0,
    mode: str = "local",  # local (L) | global (G) | probe (LP)
    probe_period: int = 0,
    source_keys: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Run the S-source PKG simulation; returns the assignment (m,)."""
    assert mode in ("local", "global", "probe")
    src = source_assignment(len(keys), n_sources, source_keys)
    assign = _simulate(
        jnp.asarray(keys, jnp.int32),
        jnp.asarray(src, jnp.int32),
        n_workers=n_workers,
        n_sources=n_sources,
        d=d,
        seed=seed,
        mode=mode,
        probe_period=max(probe_period, 1),
    )
    return np.asarray(assign)


def local_imbalance_bound(
    keys: np.ndarray,
    assign: np.ndarray,
    sources: np.ndarray,
    n_workers: int,
    n_sources: int,
) -> tuple[float, float]:
    """Return (global imbalance, sum of per-source local imbalances).

    Paper SS3.2 theorem: I(t) <= sum_j I_hat_j(t).  Exposed for tests.
    """
    per = np.zeros((n_sources, n_workers), dtype=np.int64)
    np.add.at(per, (sources, assign), 1)
    global_ld = per.sum(axis=0)
    gi = global_ld.max() - global_ld.mean()
    li = (per.max(axis=1) - per.mean(axis=1)).sum()
    return float(gi), float(li)


def head_test(count, total, theta: float, min_count: int = 1):
    """THE canonical head predicate: count/total >= theta, evaluated as
    float32(count) >= float32(theta) * float32(max(total, 1)), plus a
    min_count observation floor.  Every consumer — the offline pre-pass
    (numpy), the online scan carry and the per-block head tables (jnp) —
    must use this exact arithmetic: float32 on both paths is what keeps the
    frozen-carry online variants bit-identical to the offline ones even on
    theta-boundary counts (numpy and XLA f32 multiply/compare are both IEEE).
    """
    if isinstance(count, (np.ndarray, np.integer, int)):
        tot = np.float32(max(int(total), 1))
        frac_ok = np.float32(count) >= np.float32(theta) * tot
        return np.logical_and(np.asarray(count) >= min_count, frac_ok)
    tot = jnp.maximum(total, 1).astype(jnp.float32)
    frac_ok = count.astype(jnp.float32) >= jnp.float32(theta) * tot
    return (count >= min_count) & frac_ok


def head_threshold(n_workers: int, d: int = 2) -> float:
    """Head/tail frequency cut (DESIGN.md SS3.3).

    PKG with d choices balances iff p1 <= d/W (paper SS5; arXiv 1504.00788's
    bound degrades past it).  A key whose frequency fraction exceeds d/W
    therefore cannot be absorbed by d candidates and belongs to the head.
    """
    return d / n_workers


def adaptive_d(
    p_hat: np.ndarray,
    n_workers: int,
    d_base: int = 2,
    d_max: int = 16,
    slack: float = 2.0,
) -> np.ndarray:
    """D-Choices rule (arXiv 1510.05714; DESIGN.md SS3.3).

    A key with frequency fraction p spreads p/d(k) of the stream on each of
    its candidates; keeping that at most 1/(slack*W)-ish of the fair share
    needs d(k) >= slack * p * W.  Clipped to [d_base, d_max].
    """
    need = np.ceil(slack * np.asarray(p_hat, np.float64) * n_workers)
    return np.clip(need, d_base, d_max).astype(np.int32)


def adaptive_d_counts(
    counts,
    total,
    n_workers: int,
    d_base: int = 2,
    d_max: int = 16,
    slack: float = 2.0,
):
    """Integer-exact D-Choices rule on raw (count, total) pairs.

    Same rule as adaptive_d — d(k) = clip(ceil(slack * p * W), d_base, d_max)
    with p = count/total — but evaluated in integer arithmetic with slack as
    the rational s_num/s_den (limit_denominator(256): exact for the dyadic
    slacks used in practice), so the offline
    pre-pass (numpy int64) and the scan-carry online path (jnp int32) land on
    the same d(k) even when slack*p*W sits exactly on a ceil boundary, where
    float rounding would otherwise split them.  Works on numpy and jnp inputs;
    int32 callers need slack_num * n_workers * count < 2**31.
    """
    from fractions import Fraction

    frac = Fraction(float(slack)).limit_denominator(256)
    s_num, s_den = frac.numerator, frac.denominator
    if isinstance(counts, (np.ndarray, np.integer, int)):
        num = np.int64(s_num * n_workers) * np.asarray(counts, np.int64)
        den = np.int64(s_den) * np.int64(total)
        need = -((-num) // max(int(den), 1))
        return np.clip(need, d_base, d_max).astype(np.int32)
    num = jnp.int32(s_num * n_workers) * counts
    den = jnp.int32(s_den) * total
    need = -((-num) // jnp.maximum(den, 1))  # ceil-div, defined at total=0
    return jnp.clip(need, d_base, d_max).astype(jnp.int32)


class SpaceSavingTracker:
    """Streaming head-key tracker: weighted SPACESAVING + running total.

    Wraps applications.SpaceSaving with (a) vectorised chunked updates for
    array streams (unique+counts per chunk, heaviest offered first -- a valid
    weighted SPACESAVING schedule) and (b) frequency-*fraction* queries, which
    is what the adaptive partitioners consume.  Estimation error is bounded by
    total/capacity, so head detection at threshold theta is exact up to
    1/capacity (choose capacity >> 1/theta).
    """

    def __init__(self, capacity: int = 1024):
        self.capacity = capacity
        self._ss = SpaceSaving(capacity)
        self.total = 0

    def offer(self, key: int, weight: int = 1) -> None:
        self._ss.offer(int(key), int(weight))
        self.total += int(weight)

    def update(self, keys: np.ndarray, chunk: int = 8192) -> None:
        """Consume an array of keys in stream order (chunked internally)."""
        keys = np.asarray(keys).reshape(-1)
        for lo in range(0, len(keys), chunk):
            uniq, cnt = np.unique(keys[lo : lo + chunk], return_counts=True)
            order = np.argsort(-cnt, kind="stable")
            for k, w in zip(uniq[order], cnt[order]):
                self._ss.offer(int(k), int(w))
        self.total += len(keys)

    def guaranteed_count(self, key: int) -> int:
        """Lower bound on the true count: estimate minus inherited error."""
        k = int(key)
        return self._ss.counts.get(k, 0) - self._ss.errors.get(k, 0)

    def is_head(self, key: int, theta: float, min_count: int = 1) -> bool:
        """Streaming head query, conservative on both ends.  `min_count`
        guards against early-stream noise (with a handful of observations any
        fraction clears theta trivially); the threshold test uses the
        error-corrected count so a cold key that re-enters a saturated
        summary — inheriting the evicted minimum — cannot be mistaken for
        head when theta <= 1/capacity.  head_keys() deliberately stays on raw
        estimates: over-inclusion only costs extra splitting there, while a
        false head here breaks bounded-fanout contracts."""
        return (
            self.total > 0
            and self._ss.estimate(int(key)) >= min_count
            and self.guaranteed_count(key) >= theta * self.total
        )

    def decay(self, factor: float = 0.5) -> None:
        """Windowed/decayed mode: scale every counter (and the running total)
        by `factor`, dropping entries that reach zero.  Calling this every
        `period` messages makes the summary an exponentially-decayed window
        with half-life period/log2(1/factor) messages, so theta-relative head
        detection follows a rotating head set instead of averaging over the
        whole history (DESIGN.md SS3.3)."""
        ss = self._ss
        for k in list(ss.counts):
            c = int(ss.counts[k] * factor)
            if c <= 0:
                del ss.counts[k]
                del ss.errors[k]
            else:
                ss.counts[k] = c
                ss.errors[k] = int(ss.errors[k] * factor)
        self.total = int(self.total * factor)

    def head_counts(
        self, theta: float, min_count: int = 1
    ) -> tuple[np.ndarray, np.ndarray, int]:
        """head_keys on raw integer counts: (ids sorted, counts aligned, total).

        This is what the integer-exact adaptive_d_counts rule consumes; the
        predicate is the canonical head_test (float32 + min_count floor), so
        the offline pre-pass and the scan-carry online path agree bit-for-bit.
        """
        if self.total == 0:
            return np.empty(0, np.int64), np.empty(0, np.int64), 0
        items = sorted(
            (k, c)
            for k, c in self._ss.counts.items()
            if bool(head_test(c, self.total, theta, min_count))
        )
        ids = np.asarray([k for k, _ in items], np.int64)
        cnt = np.asarray([c for _, c in items], np.int64)
        return ids, cnt, self.total

    def head_keys(self, theta: float) -> tuple[np.ndarray, np.ndarray]:
        """All tracked keys with estimated frequency fraction >= theta.

        Returns (ids (h,) int64 sorted, p_hat (h,) float64 aligned).
        """
        if self.total == 0:
            return np.empty(0, np.int64), np.empty(0, np.float64)
        items = [
            (k, c / self.total)
            for k, c in self._ss.counts.items()
            if c / self.total >= theta
        ]
        items.sort()
        ids = np.asarray([k for k, _ in items], np.int64)
        p = np.asarray([p for _, p in items], np.float64)
        return ids, p


# ---------------------------------------------------------------------------
# Online (scan-carry) SPACESAVING — DESIGN.md SS3.3 "Online estimation".
# ---------------------------------------------------------------------------


class OnlineSS(NamedTuple):
    """SPACESAVING summary as flat arrays, carried through lax.scan.

    keys   (C,) int32  slot key ids; a slot is live iff counts > 0
    counts (C,) int32  estimated counts (upper bounds)
    errors (C,) int32  inherited over-estimation per slot
    total  ()   int32  messages observed (decayed total in windowed mode)
    """

    keys: jnp.ndarray
    counts: jnp.ndarray
    errors: jnp.ndarray
    total: jnp.ndarray


def online_ss_init(capacity: int) -> OnlineSS:
    return OnlineSS(
        keys=jnp.full((capacity,), -1, jnp.int32),
        counts=jnp.zeros((capacity,), jnp.int32),
        errors=jnp.zeros((capacity,), jnp.int32),
        total=jnp.int32(0),
    )


def online_ss_update(state: OnlineSS, key, weight=1) -> OnlineSS:
    """One SPACESAVING offer as a pure array transition (jit/scan safe).

    Mirrors applications.SpaceSaving.offer: tracked key -> increment; untracked
    key -> evict the minimum-count slot (an empty slot is a zero-count victim,
    so fill-then-evict needs no separate branch), inheriting its count as the
    new entry's error.  O(capacity) vector ops per element.
    """
    k = jnp.asarray(key, jnp.int32)
    w = jnp.asarray(weight, jnp.int32)
    live = state.counts > 0
    match = live & (state.keys == k)
    found = match.any()
    slot = jnp.where(found, jnp.argmax(match), jnp.argmin(state.counts))
    c_slot = state.counts[slot]
    # found: count+w, same error; miss: victim_count + w, error = victim_count
    new_count = c_slot + w
    new_error = jnp.where(found, state.errors[slot], c_slot)
    return OnlineSS(
        keys=state.keys.at[slot].set(k),
        counts=state.counts.at[slot].set(new_count),
        errors=state.errors.at[slot].set(new_error),
        total=state.total + w,
    )


def online_ss_decay(state: OnlineSS, shift: int = 1) -> OnlineSS:
    """Halve all counters `shift` times (integer floor) plus the total.

    Applied every `decay_period` messages this turns the summary into an
    exponentially-decayed window (half-life ~ decay_period messages for
    shift=1); slots whose count reaches zero free themselves because liveness
    is counts > 0.  Floor halving keeps the invariant errors <= counts.
    """
    return OnlineSS(
        keys=state.keys,
        counts=state.counts >> shift,
        errors=state.errors >> shift,
        total=state.total >> shift,
    )


def online_ss_estimate(state: OnlineSS, key) -> jnp.ndarray:
    """Estimated count of `key` (0 if untracked) — upper bound as in offline."""
    k = jnp.asarray(key, jnp.int32)
    match = (state.counts > 0) & (state.keys == k)
    return jnp.where(match, state.counts, 0).max()


def online_ss_from_tracker(tracker: SpaceSavingTracker, capacity: int) -> OnlineSS:
    """Warm-start an OnlineSS from a Python-side tracker (top-`capacity`)."""
    items = tracker._ss.counts
    top = sorted(items, key=items.get, reverse=True)[:capacity]  # type: ignore[arg-type]
    state = online_ss_init(capacity)
    n = len(top)
    if n == 0:
        return state._replace(total=jnp.int32(tracker.total))
    return OnlineSS(
        keys=state.keys.at[:n].set(jnp.asarray(top, jnp.int32)),
        counts=state.counts.at[:n].set(
            jnp.asarray([items[k] for k in top], jnp.int32)
        ),
        errors=state.errors.at[:n].set(
            jnp.asarray([tracker._ss.errors[k] for k in top], jnp.int32)
        ),
        total=jnp.int32(tracker.total),
    )


def online_ss_head_table(
    state: OnlineSS,
    n_workers: int,
    d: int = 2,
    d_max: int = 16,
    theta: Optional[float] = None,
    slack: float = 2.0,
    min_count: int = 8,
    any_worker: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Emit one (tbl_keys, tbl_ncand) head table from a summary state.

    THE shared emit: `online_head_tables` calls this once per block and the
    chunked driver (parallel.chunked_driver) calls it from inside its carried
    scan, so both paths derive candidate counts from identical arithmetic —
    canonical head_test predicate, integer-exact adaptive_d_counts, and
    W_SENTINEL head slots under `any_worker`.  Slot ncand is d(k) for head
    slots and `d` otherwise (lookup miss == tail hit == plain PKG).
    """
    theta_f = head_threshold(n_workers, d) if theta is None else float(theta)
    is_head = head_test(state.counts, state.total, theta_f, min_count)
    if any_worker:
        head_nc = jnp.full_like(state.counts, jnp.int32(W_SENTINEL))
    else:
        head_nc = adaptive_d_counts(
            state.counts, state.total, n_workers,
            d_base=d, d_max=d_max, slack=slack,
        )
    return state.keys, jnp.where(is_head, head_nc, d).astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=(
        "block", "capacity", "n_workers", "d", "d_max", "theta", "slack",
        "min_count", "decay_period", "any_worker",
    ),
)
def online_head_tables(
    keys: jnp.ndarray,
    block: int,
    capacity: int,
    n_workers: int,
    d: int = 2,
    d_max: int = 16,
    theta: Optional[float] = None,
    slack: float = 2.0,
    min_count: int = 8,
    decay_period: int = 0,
    any_worker: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-vector-block head tables for the Pallas adaptive router.

    Runs the online tracker over `keys` (N, divisible by block) and emits, for
    every block b, the summary state *before* consuming block b — so a router
    reading table b sees head decisions stale by at most `block` messages,
    mirroring pkg_partition_batched's stale-loads contract (DESIGN.md SS2).

    Returns (tbl_keys (N/block, capacity) int32, tbl_ncand same shape): slot
    ncand is the integer-exact d(k) for head slots and `d` otherwise, so a
    lookup miss and a tail hit are indistinguishable — both route as PKG.
    With `any_worker=True` (W-Choices) head slots carry W_SENTINEL instead of
    d(k), flagging "route to the global least-loaded worker" to the kernel's
    global-argmin path — consume such tables with the router's w_mode=True
    (DESIGN.md SS3.3).
    """
    N = keys.shape[0]
    assert N % block == 0, (N, block)
    kb = keys.astype(jnp.int32).reshape(N // block, block)
    t_idx = jnp.arange(N // block, dtype=jnp.int32)

    def emit(state: OnlineSS):
        return online_ss_head_table(
            state, n_workers, d=d, d_max=d_max, theta=theta,
            slack=slack, min_count=min_count, any_worker=any_worker,
        )

    def step(state, inp):
        blk, b = inp
        out = emit(state)
        if decay_period > 0:
            do = (b * block) % decay_period < block  # crossed a period boundary
            state = lax.cond(
                (b > 0) & do, lambda s: online_ss_decay(s), lambda s: s, state
            )
        state = lax.scan(lambda s, k: (online_ss_update(s, k), None), state, blk)[0]
        return state, out

    _, (tbl_keys, tbl_ncand) = lax.scan(
        step, online_ss_init(capacity), (kb, t_idx)
    )
    return tbl_keys, tbl_ncand
