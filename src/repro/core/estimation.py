"""Load and frequency estimation (paper SS3.2, SS6.2 Q2; DESIGN.md SS3.3).

Two kinds of estimators live here:

1. Multi-source *load* simulation.  A single lax.scan walks the stream in
   global arrival order, carrying
     local_est : (S, n)  per-source local load estimates
     global_ld : (n,)    true worker loads
   Each message is routed by its source's *local* estimate (technique L), by
   the true loads (G, the global oracle), or by local estimates that are
   periodically reset to the true loads (LP, probing every probe_period
   messages).  Source assignment of messages is either round-robin shuffle
   (the default in the paper) or key grouping on a secondary key (Fig 8's
   skewed-sources setup).

2. Streaming *frequency* estimation for the adaptive multi-choice
   partitioners (arXiv 1510.05714).  SpaceSavingTracker identifies the head
   keys of the stream in O(capacity) space; head_threshold / adaptive_d
   encode the head/tail rule and the skew-adaptive choice count d(k)
   (DESIGN.md SS3.3).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.applications import SpaceSaving
from repro.core.hashing import hash_choices

__all__ = [
    "simulate_sources",
    "source_assignment",
    "local_imbalance_bound",
    "SpaceSavingTracker",
    "head_threshold",
    "adaptive_d",
]


def source_assignment(
    n_msgs: int,
    n_sources: int,
    source_keys: Optional[np.ndarray] = None,
    seed: int = 17,
) -> np.ndarray:
    """Message -> source map: shuffle (round-robin) or KG on source_keys."""
    if source_keys is None:
        return (np.arange(n_msgs, dtype=np.int64) % n_sources).astype(np.int32)
    h = np.asarray(
        hash_choices(jnp.asarray(source_keys, jnp.int32), n_sources, d=1, seed=seed)
    )[..., 0]
    return h.astype(np.int32)


@functools.partial(
    jax.jit,
    static_argnames=("n_workers", "n_sources", "d", "seed", "mode", "probe_period"),
)
def _simulate(
    keys: jnp.ndarray,
    sources: jnp.ndarray,
    n_workers: int,
    n_sources: int,
    d: int,
    seed: int,
    mode: str,
    probe_period: int,
) -> jnp.ndarray:
    cand = hash_choices(keys, n_workers, d=d, seed=seed)  # (m, d)
    m = keys.shape[0]
    t_idx = jnp.arange(m, dtype=jnp.int32)

    def step(state, inp):
        local_est, global_ld = state
        c, s, t = inp
        if mode == "probe":
            do_probe = (t % probe_period) == 0
            local_est = jnp.where(
                do_probe, jnp.broadcast_to(global_ld, local_est.shape), local_est
            )
        if mode == "global":
            lc = global_ld[c]
        else:
            lc = local_est[s, c]
        choice = c[jnp.argmin(lc)]
        local_est = local_est.at[s, choice].add(1)
        global_ld = global_ld.at[choice].add(1)
        return (local_est, global_ld), choice

    state0 = (
        jnp.zeros((n_sources, n_workers), jnp.int32),
        jnp.zeros((n_workers,), jnp.int32),
    )
    _, assign = lax.scan(step, state0, (cand, sources, t_idx))
    return assign


def simulate_sources(
    keys: np.ndarray,
    n_workers: int,
    n_sources: int = 5,
    d: int = 2,
    seed: int = 0,
    mode: str = "local",  # local (L) | global (G) | probe (LP)
    probe_period: int = 0,
    source_keys: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Run the S-source PKG simulation; returns the assignment (m,)."""
    assert mode in ("local", "global", "probe")
    src = source_assignment(len(keys), n_sources, source_keys)
    assign = _simulate(
        jnp.asarray(keys, jnp.int32),
        jnp.asarray(src, jnp.int32),
        n_workers=n_workers,
        n_sources=n_sources,
        d=d,
        seed=seed,
        mode=mode,
        probe_period=max(probe_period, 1),
    )
    return np.asarray(assign)


def local_imbalance_bound(
    keys: np.ndarray,
    assign: np.ndarray,
    sources: np.ndarray,
    n_workers: int,
    n_sources: int,
) -> tuple[float, float]:
    """Return (global imbalance, sum of per-source local imbalances).

    Paper SS3.2 theorem: I(t) <= sum_j I_hat_j(t).  Exposed for tests.
    """
    per = np.zeros((n_sources, n_workers), dtype=np.int64)
    np.add.at(per, (sources, assign), 1)
    global_ld = per.sum(axis=0)
    gi = global_ld.max() - global_ld.mean()
    li = (per.max(axis=1) - per.mean(axis=1)).sum()
    return float(gi), float(li)


def head_threshold(n_workers: int, d: int = 2) -> float:
    """Head/tail frequency cut (DESIGN.md SS3.3).

    PKG with d choices balances iff p1 <= d/W (paper SS5; arXiv 1504.00788's
    bound degrades past it).  A key whose frequency fraction exceeds d/W
    therefore cannot be absorbed by d candidates and belongs to the head.
    """
    return d / n_workers


def adaptive_d(
    p_hat: np.ndarray,
    n_workers: int,
    d_base: int = 2,
    d_max: int = 16,
    slack: float = 2.0,
) -> np.ndarray:
    """D-Choices rule (arXiv 1510.05714; DESIGN.md SS3.3).

    A key with frequency fraction p spreads p/d(k) of the stream on each of
    its candidates; keeping that at most 1/(slack*W)-ish of the fair share
    needs d(k) >= slack * p * W.  Clipped to [d_base, d_max].
    """
    need = np.ceil(slack * np.asarray(p_hat, np.float64) * n_workers)
    return np.clip(need, d_base, d_max).astype(np.int32)


class SpaceSavingTracker:
    """Streaming head-key tracker: weighted SPACESAVING + running total.

    Wraps applications.SpaceSaving with (a) vectorised chunked updates for
    array streams (unique+counts per chunk, heaviest offered first -- a valid
    weighted SPACESAVING schedule) and (b) frequency-*fraction* queries, which
    is what the adaptive partitioners consume.  Estimation error is bounded by
    total/capacity, so head detection at threshold theta is exact up to
    1/capacity (choose capacity >> 1/theta).
    """

    def __init__(self, capacity: int = 1024):
        self.capacity = capacity
        self._ss = SpaceSaving(capacity)
        self.total = 0

    def offer(self, key: int, weight: int = 1) -> None:
        self._ss.offer(int(key), int(weight))
        self.total += int(weight)

    def update(self, keys: np.ndarray, chunk: int = 8192) -> None:
        """Consume an array of keys in stream order (chunked internally)."""
        keys = np.asarray(keys).reshape(-1)
        for lo in range(0, len(keys), chunk):
            uniq, cnt = np.unique(keys[lo : lo + chunk], return_counts=True)
            order = np.argsort(-cnt, kind="stable")
            for k, w in zip(uniq[order], cnt[order]):
                self._ss.offer(int(k), int(w))
        self.total += len(keys)

    def guaranteed_count(self, key: int) -> int:
        """Lower bound on the true count: estimate minus inherited error."""
        k = int(key)
        return self._ss.counts.get(k, 0) - self._ss.errors.get(k, 0)

    def is_head(self, key: int, theta: float, min_count: int = 1) -> bool:
        """Streaming head query, conservative on both ends.  `min_count`
        guards against early-stream noise (with a handful of observations any
        fraction clears theta trivially); the threshold test uses the
        error-corrected count so a cold key that re-enters a saturated
        summary — inheriting the evicted minimum — cannot be mistaken for
        head when theta <= 1/capacity.  head_keys() deliberately stays on raw
        estimates: over-inclusion only costs extra splitting there, while a
        false head here breaks bounded-fanout contracts."""
        return (
            self.total > 0
            and self._ss.estimate(int(key)) >= min_count
            and self.guaranteed_count(key) >= theta * self.total
        )

    def head_keys(self, theta: float) -> tuple[np.ndarray, np.ndarray]:
        """All tracked keys with estimated frequency fraction >= theta.

        Returns (ids (h,) int64 sorted, p_hat (h,) float64 aligned).
        """
        if self.total == 0:
            return np.empty(0, np.int64), np.empty(0, np.float64)
        items = [
            (k, c / self.total)
            for k, c in self._ss.counts.items()
            if c / self.total >= theta
        ]
        items.sort()
        ids = np.asarray([k for k, _ in items], np.int64)
        p = np.asarray([p for _, p in items], np.float64)
        return ids, p
