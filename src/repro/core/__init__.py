"""Core PKG library: the paper's contribution as composable JAX modules."""
from repro.core.hashing import (
    derive_seeds,
    derive_seeds_np,
    hash_choices,
    hash_choices_np,
    splitmix32,
    splitmix32_np,
)
from repro.core.routing import (
    ROUTING_POLICIES,
    KGPolicy,
    LoadLedger,
    PoTCPolicy,
    RoundRobinPolicy,
    RoutingPolicy,
    WChoicesPolicy,
    host_policy_names,
    make_policy,
)
from repro.core.partitioners import (
    PARTITIONERS,
    d_choices_kernel_partition,
    d_choices_partition,
    hash_partition,
    off_greedy_partition,
    on_greedy_partition,
    online_d_choices_partition,
    online_w_choices_partition,
    pkg_partition,
    pkg_partition_batched,
    potc_static_partition,
    shuffle_partition,
    w_choices_kernel_partition,
    w_choices_partition,
)
from repro.core.estimation import (
    OnlineSS,
    SpaceSavingTracker,
    W_SENTINEL,
    adaptive_d,
    adaptive_d_counts,
    head_test,
    head_threshold,
    local_imbalance_bound,
    online_head_tables,
    online_ss_decay,
    online_ss_estimate,
    online_ss_from_tracker,
    online_ss_init,
    online_ss_update,
    simulate_sources,
    source_assignment,
)
from repro.core.metrics import (
    avg_imbalance_fraction,
    disagreement,
    final_imbalance_fraction,
    imbalance,
    imbalance_series,
    keys_per_worker,
    loads_from_assignment,
    tenant_imbalance_report,
)
from repro.core.streams import (
    DRIFT_SCENARIOS,
    PAPER_DATASETS,
    SCALE_SCENARIOS,
    DriftScenario,
    ScaleScenario,
    StreamSpec,
    abrupt_shift_stream,
    drift_stream,
    graph_edge_stream,
    lognormal_stream,
    matched_trace_stream,
    multi_tenant_stream,
    uniform_stream,
    zipf_probs,
    zipf_stream,
)
from repro.core.storm_sim import (
    QueueModel,
    aggregation_memory,
    aggregation_message_overhead,
)

__all__ = [k for k in dir() if not k.startswith("_")]
