"""Batched serving engine: prefill + greedy decode over the model's KV caches.

`serve_step` (one token for the whole batch against a pre-sized cache) is the
function the decode_32k / long_500k dry-run cells lower.  The Python-level
`generate` drives the jitted step for the examples and tests.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import decode_step, init_cache

__all__ = ["ServeEngine", "make_serve_step"]


def _id_sh(name, x):
    return x


def make_serve_step(cfg, sh: Callable = _id_sh):
    """Returns serve_step(params, cache, batch, pos) -> (logits, new_cache)."""

    def serve_step(params, cache, batch, pos):
        return decode_step(params, cache, batch, pos, cfg, sh=sh)

    return serve_step


class ServeEngine:
    def __init__(self, cfg, params, max_len: int = 4096, cache_dtype=jnp.bfloat16):
        assert cfg.frontend == "tokens", "ServeEngine drives token frontends"
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        self._step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    def generate(self, prompts: jnp.ndarray, n_new: int, greedy: bool = True,
                 key: Optional[jax.Array] = None):
        """prompts (B, S0) int32 -> (B, S0 + n_new) tokens (greedy/sampled).

        Prefill runs through the same single-token step (cache-building pass);
        production prefill uses the Pallas flash kernel via the prefill path.
        """
        B, S0 = prompts.shape
        cache = init_cache(self.cfg, B, self.max_len, self.cache_dtype)
        toks = prompts
        logits = None
        for t in range(S0):
            logits, cache = self._step(
                self.params, cache, {"tokens": toks[:, t : t + 1]}, jnp.int32(t)
            )
        out = [toks]
        cur = None
        for i in range(n_new):
            if cur is None:
                nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            else:
                logits, cache = self._step(
                    self.params, cache, {"tokens": cur}, jnp.int32(S0 + i - 1)
                )
                nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            if not greedy and key is not None:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits[:, -1]).astype(jnp.int32)[:, None]
            cur = nxt
            out.append(nxt)
        return jnp.concatenate(out, axis=1)
