from repro.serving.scheduler import (
    KGScheduler,
    PoTCScheduler,
    RoundRobinScheduler,
    WChoicesScheduler,
)
from repro.serving.engine import ServeEngine
