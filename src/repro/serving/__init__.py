from repro.serving.scheduler import (
    KGScheduler,
    PolicyScheduler,
    PoTCScheduler,
    RoundRobinScheduler,
    WChoicesScheduler,
)
from repro.serving.sim import Autoscaler, SimResult, simulate_serving
from repro.serving.engine import ServeEngine

__all__ = [
    "KGScheduler",
    "PolicyScheduler",
    "PoTCScheduler",
    "RoundRobinScheduler",
    "WChoicesScheduler",
    "Autoscaler",
    "SimResult",
    "simulate_serving",
    "ServeEngine",
]
