from repro.serving.scheduler import PoTCScheduler, RoundRobinScheduler, KGScheduler
from repro.serving.engine import ServeEngine
