"""Discrete-event serving simulator: the closed request/completion loop,
now failure- and overload-aware.

The schedulers' load accounting only means "outstanding work" if something
ever calls ``complete()`` — this module is that something.  Requests arrive
at a fixed rate (``utilization`` of aggregate replica capacity), each replica
is a FIFO queue serving one request at a time, and a completion event fires
``scheduler.complete(replica, cost)`` before the next arrival is routed, so
the scheduler's ledger tracks genuinely outstanding work (the metric
``launch/serve.py`` used to mislabel).

On top of the queueing model sits the serving-edge tradeoff the paper's §7
cluster story implies (DESIGN.md §8): each replica keeps an LRU **prefix
cache** over session keys (capacity ``cache_capacity``); a request hits iff
its session key is resident on the replica it lands on.  Sticky KG maximizes
hit-rate and ruins balance under skew; round-robin is the opposite corner;
PoTC/W-Choices trade between them.  multi-tenant streams additionally get
per-tenant SLO accounting via core.metrics.tenant_imbalance_report.

**Overload semantics** (queue-based load leveling + throttling): with a
``queue_bound`` B, a replica admits at most B queued-or-in-service requests;
an arrival routed to a full replica is **shed** — released from the ledger
immediately, counted in ``SimResult.shed``, never served.  Shedding makes
``utilization > 1`` meaningful: the bounded queues clamp per-request latency
at ~(B · max cost) while the surplus arrivals are rejected, so p99 stays
bounded where the unbounded simulator's queues (and latencies) diverged
silently.  Without a queue_bound, ``utilization >= 1`` has no steady state —
``outstanding_imbalance`` is then dominated by the divergence, and the
simulator warns.

**Failure semantics**: ``kill_schedule`` is a sequence of (time, replica)
events.  At a kill, the replica's live-mask bit drops (LoadLedger.kill), its
prefix cache is wiped, and every request still pending on it is drained and
**requeued** through ``scheduler.route`` — the policy re-decides under the
live mask, so each policy redistributes the dead replica's keys by its own
mechanism (KG rehash chain, RR slot skip, PoTC/W-Choices live-candidate
argmin; see core.routing).  Requeued requests keep their original arrival
time, so their enqueue→completion latency includes the redo cost; nothing is
lost (``completed + shed == m`` always).  ``revive_schedule`` brings a
replica back with a **cold** cache, so the post-revival hit-rate dip
measures the cache re-warm cost.

**Heterogeneous replicas** (arXiv 1705.09073): when the scheduler's ledger
carries ``capacities``, replica r serves at rate ``c_r`` — a request of cost
c occupies it for ``c / c_r`` wall-clock — and the arrival rate is
``utilization`` of the *initial live capacity* ``sum(c_r)`` rather than the
replica count.  The outstanding-imbalance samples are capacity-normalized
(``load_r / c_r``), so uniform capacities reproduce the homogeneous
simulator bit-for-bit.  Ledger accounting stays in cost units; only wall
time and the balance metric rescale.

**Elastic semantics**: an ``Autoscaler`` grows and shrinks the live replica
pool on a queue-depth signal (outstanding work per unit live capacity, in
mean-cost units).  It reuses the kill/revive machinery verbatim — scale-down
is ``on_kill`` (drain + requeue through the policy), scale-up is
``on_revive`` (cold cache) — and keeps the active set a *contiguous prefix*
of the replica ids: scale-up revives the lowest dead id, scale-down kills
the highest live one.  That prefix discipline is the consistent-hash-style
handoff: a rescale only moves the keys that the policy's own failover chain
maps onto (or off) the toggled replica, so every other replica's prefix
cache survives the rescale untouched.  Scale actions are recorded in
``SimResult.scale_events`` and the drain curve in
``SimResult.sample_outstanding`` (benchmarks/bench_hetero_elastic.py gates
the recovery time from these).
"""
from __future__ import annotations

import dataclasses
import heapq
import warnings
from collections import OrderedDict, deque
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.metrics import avg_imbalance_fraction, tenant_imbalance_report

__all__ = ["Autoscaler", "SimResult", "simulate_serving"]

Schedule = Sequence[Tuple[float, int]]  # (event time, replica id)


@dataclasses.dataclass
class Autoscaler:
    """Reactive pool autoscaler for simulate_serving.

    Every ``check_every`` arrivals (and at least ``cooldown`` arrivals after
    the previous action) the signal

        outstanding live work / (live capacity * mean cost)

    — roughly "queued requests per unit replica" — is compared against the
    ``high``/``low`` watermarks: above ``high`` the lowest dead replica id is
    revived (cold cache), below ``low`` the highest live id is killed (its
    pending work drains and requeues through the policy).  The pool stays in
    [min_replicas, max_replicas]; the run starts with ``initial`` live
    replicas (default min_replicas), the rest pre-killed.
    """

    min_replicas: int
    max_replicas: int
    initial: Optional[int] = None
    high: float = 4.0
    low: float = 0.5
    check_every: int = 256
    cooldown: int = 512

    def __post_init__(self):
        if self.initial is None:
            self.initial = self.min_replicas
        if not 1 <= self.min_replicas <= self.initial <= self.max_replicas:
            raise ValueError(
                f"need 1 <= min {self.min_replicas} <= initial "
                f"{self.initial} <= max {self.max_replicas}"
            )
        if not self.low < self.high:
            raise ValueError(f"low {self.low} must be < high {self.high}")
        if self.check_every < 1 or self.cooldown < 0:
            raise ValueError("check_every >= 1 and cooldown >= 0 required")


@dataclasses.dataclass
class SimResult:
    """Everything the benches and demos report (assign/hit/latency/shed are
    per-request arrays, the rest scalar summaries).

    **Streaming mode** (simulate_serving fed a chunk iterator): the
    per-request arrays are not materialized — ``assign``/``hit``/
    ``shed_mask`` come back empty, ``latency`` holds the (reservoir-bounded)
    completed-request latencies the percentiles were computed from, and
    ``assign_imbalance`` is the checkpointed online estimate.  All scalar
    aggregates (hit_rate, completed, shed, makespan, peak, percentiles at
    reservoir scale) match the array-mode run exactly; ``assign_hist`` (the
    final per-replica request histogram) is filled in both modes."""

    assign: np.ndarray          # (m,) replica per request (final, post-requeue)
    hit: np.ndarray             # (m,) bool prefix-cache hit at admission
    hit_rate: float             # mean(hit)
    assign_imbalance: float     # avg imbalance fraction of routed work
    outstanding_imbalance: float  # mean I(t)/outstanding over post-warmup
    #   samples; nan when the run is too short (< n_replicas requests) to
    #   produce any
    peak_outstanding: float     # max outstanding work on any replica, ever
    session_fanout_max: int     # worst-case replicas touched by one session
    completed: int              # completions delivered to the scheduler
    makespan: float             # last completion time
    latency: np.ndarray         # (m,) enqueue->completion time; nan if shed
    latency_p50: float          # percentiles over completed requests
    latency_p99: float
    latency_p999: float
    shed: int                   # requests rejected at a full queue_bound
    shed_mask: np.ndarray       # (m,) bool, True where the request was shed
    requeued: int               # pending requests redistributed off dead replicas
    sample_times: np.ndarray    # outstanding-imbalance sample times (post-warmup)
    sample_imbalance: np.ndarray  # I(t)/outstanding at those times (live
    #   replicas; capacity-normalized loads when the ledger has capacities)
    sample_outstanding: np.ndarray  # total outstanding work (cost units, live
    #   replicas) at those times — the queue-drain curve rescales ride on
    tenant_report: Optional[dict] = None
    scale_events: list = dataclasses.field(default_factory=list)
    #   (time, +1|-1, replica) per autoscaler action, in order
    assign_hist: Optional[np.ndarray] = None
    #   (n,) int64 final routed-request histogram (post-requeue), both modes


def _percentile(lat: np.ndarray, q: float) -> float:
    return float(np.percentile(lat, q)) if len(lat) else float("nan")


def simulate_serving(
    scheduler,
    keys,
    costs=None,
    tenants=None,
    *,
    utilization: float = 0.7,
    cache_capacity: int = 64,
    slo: float = 0.05,
    sample_every: Optional[int] = None,
    slo_checkpoints: int = 50,
    queue_bound: Optional[int] = None,
    kill_schedule: Optional[Schedule] = None,
    revive_schedule: Optional[Schedule] = None,
    strict_ledger: bool = True,
    autoscaler: Optional[Autoscaler] = None,
) -> SimResult:
    """Drive ``scheduler`` (route/complete/loads) through a request stream.

    keys (m,) are session ids; costs (m,) are service times (default 1.0).
    Arrivals are evenly spaced so offered load is ``utilization`` of the
    aggregate service rate; replicas serve FIFO at unit rate (or rate
    ``c_r`` when the scheduler's ledger carries capacities — see the module
    docstring), and every completion with finish time <= the current arrival
    is delivered via ``scheduler.complete`` before the arrival is routed.
    After the last arrival the queue drains fully, so every admitted request
    completes: ``completed + shed == m`` and a correct scheduler's ledger
    ends at exactly zero (enforced here when the scheduler carries a
    LoadLedger — ``strict_ledger`` arms its over-release guard for the run).

    ``queue_bound`` bounds each replica's FIFO (admission control: overflow
    arrivals are shed); ``kill_schedule`` / ``revive_schedule`` are
    (time, replica) sequences driving mid-stream replica failure and revival
    — see the module docstring for the overload and failure semantics.
    ``autoscaler`` (an Autoscaler) elastically grows/shrinks the live pool
    on the same kill/revive machinery.  ``utilization >= 1`` without a
    queue_bound diverges and warns.

    With ``tenants`` given, the result carries a per-tenant SLO report
    (core.metrics.tenant_imbalance_report at threshold ``slo``).

    **Streaming mode**: ``keys`` may instead be an *iterator of int chunks*
    (anything without ``len()`` — core.traces readers, ChunkedRouter feeds,
    core.streams.stream_chunks).  The simulator then runs with O(distinct
    keys + outstanding) memory instead of O(events): per-request arrays are
    not materialized (see SimResult), costs/tenants must be None (unit
    costs), ``sample_every`` defaults to 4096 (the stream length is unknown
    up front), and ``assign_imbalance`` is the mean of checkpointed online
    imbalance fractions rather than the retrospective prefix series.  Every
    scalar aggregate — hit_rate, completed, shed, requeued, makespan, peak,
    final histogram, latency percentiles while completions fit the 65536
    reservoir — is identical to feeding the same events as one array.
    """
    streaming = not hasattr(keys, "__len__")
    n = len(scheduler.loads)
    if streaming:
        if costs is not None:
            raise ValueError(
                "streaming keys (chunk iterator) require costs=None: "
                "per-request costs would need a second aligned stream"
            )
        if tenants is not None:
            raise ValueError(
                "streaming keys (chunk iterator) require tenants=None: the "
                "SLO report needs the materialized assignment"
            )
        chunk_iter = keys
        m = None  # unknown until the stream is drained
    else:
        keys = np.asarray(keys).reshape(-1)
        m = len(keys)
        chunk_iter = (keys,)
        if costs is None:
            costs = np.ones(m, dtype=np.float64)
        else:
            costs = np.asarray(costs, dtype=np.float64).reshape(-1)
            if len(costs) != m:
                raise ValueError(f"costs length {len(costs)} != {m}")
    if not 0.0 < utilization:
        raise ValueError(f"utilization must be positive, got {utilization}")
    if queue_bound is not None and queue_bound < 1:
        raise ValueError(f"queue_bound must be >= 1, got {queue_bound}")
    if utilization >= 1.0 and queue_bound is None:
        warnings.warn(
            f"utilization={utilization} >= 1 with unbounded queues: offered "
            "load exceeds aggregate capacity, queues and latencies diverge, "
            "and outstanding_imbalance measures the divergence rather than "
            "any steady state — pass queue_bound to shed the surplus",
            RuntimeWarning,
            stacklevel=2,
        )
    ledger = getattr(scheduler, "ledger", None)
    if (kill_schedule or revive_schedule or autoscaler) and ledger is None:
        raise ValueError(
            "kill/revive schedules and autoscaling need a LoadLedger-backed "
            "scheduler (PolicyScheduler) so the live mask reaches the policy"
        )
    if ledger is not None and strict_ledger:
        ledger.strict = True
    capacities = ledger.capacities if ledger is not None else None
    rates = None if capacities is None else np.asarray(capacities, np.float64)
    # only positive-rate replicas can ever serve; the autoscaler must not
    # revive a zero-capacity one (the ledger already masks it dead)
    eligible = np.ones(n, dtype=bool) if rates is None else rates > 0
    if autoscaler is not None:
        if autoscaler.max_replicas > int(eligible.sum()):
            raise ValueError(
                f"autoscaler max_replicas {autoscaler.max_replicas} exceeds "
                f"the {int(eligible.sum())} positive-capacity replicas"
            )
        for r in np.flatnonzero(eligible)[autoscaler.initial:]:
            ledger.kill(int(r))  # pre-killed: nothing pending to drain yet
    mean_cost = 1.0 if streaming else float(costs.mean())
    # offered load is `utilization` of the INITIAL live service capacity
    # (replica count when rates are None) — with neither capacities nor an
    # autoscaler this is exactly the old mean(cost)/(utilization*n) spacing
    live0 = ledger.live_mask() if ledger is not None else None
    if live0 is None:
        agg0 = float(n) if rates is None else float(rates.sum())
    else:
        agg0 = (
            float(live0.sum()) if rates is None
            else float(rates[live0].sum())
        )
    dt = mean_cost / (utilization * agg0)
    if sample_every is None:
        # streaming: m is unknown up front, so use a fixed cadence
        sample_every = 4096 if streaming else max(m // 256, 1)

    # control events: (time, kind, replica); kills sort before revives at
    # equal times so a kill+revive pair at t is a cache wipe, not a no-op
    ctrl = deque(sorted(
        [(float(t), 0, int(r)) for t, r in (kill_schedule or [])]
        + [(float(t), 1, int(r)) for t, r in (revive_schedule or [])]
    ))

    # heap entries carry a per-replica generation; a kill bumps gen[r] so
    # the dead replica's in-flight completions are invalidated in O(1);
    # arrival rides last in the tuple so requeues keep their original time
    # without an O(m) arrival array
    heap: list[tuple[float, int, int, float, int, float]] = []
    #   (fin, r, gen, cost, idx, arrival)
    gen = [0] * n
    pending: list[deque] = [deque() for _ in range(n)]  # (idx, key, cost, arr)
    free_at = np.zeros(n, dtype=np.float64)
    caches = [OrderedDict() for _ in range(n)]
    if streaming:
        assign = hit = shed_mask = latency = None
    else:
        assign = np.empty(m, dtype=np.int32)
        hit = np.zeros(m, dtype=bool)
        shed_mask = np.zeros(m, dtype=bool)
        latency = np.full(m, np.nan, dtype=np.float64)
    hist = np.zeros(n, dtype=np.int64)  # routed-request counts, post-requeue
    hit_count = 0
    # completed-latency reservoir (streaming): exact multiset while the run
    # fits, uniform sample (algorithm R, fixed seed) beyond — so percentiles
    # at differential-test scale match array mode exactly
    lat_cap = 1 << 16
    lat_res: list[float] = []
    lat_seen = 0
    lat_rng = np.random.default_rng(0x13D7) if streaming else None
    hist_samples: list[float] = []  # online I(t)/t checkpoints (streaming)
    # session fanout as per-key replica bitmasks (arbitrary-precision ints):
    # same max-popcount metric as the old dict-of-sets at a fraction of the
    # per-key footprint, which is what bounds streaming RSS at 1e6+ keys
    fanout: dict[int, int] = {}
    sample_ts: list[float] = []
    samples: list[float] = []
    samples_out: list[float] = []
    scale_events: list[tuple] = []
    last_scale = -1 if autoscaler is None else -autoscaler.cooldown - 1
    peak = 0.0
    completed = 0
    requeued = 0
    shed = 0
    makespan = 0.0

    def cache_insert(r: int, k: int) -> None:
        cache = caches[r]
        cache[k] = True
        cache.move_to_end(k)
        if len(cache) > cache_capacity:
            cache.popitem(last=False)

    def enqueue(idx: int, k: int, c: float, now: float, r: int, arr: float) -> None:
        start = max(now, float(free_at[r]))
        # wall-clock occupancy is cost / service rate; ledger units stay cost
        dur = c if rates is None else c / float(rates[r])
        free_at[r] = start + dur
        pending[r].append((idx, k, c, arr))
        heapq.heappush(heap, (start + dur, r, gen[r], c, idx, arr))

    def on_kill(now: float, r: int) -> None:
        nonlocal requeued, shed, peak
        ledger.kill(r)
        gen[r] += 1  # invalidate the dead replica's in-flight completions
        caches[r].clear()  # revival starts cold: re-warm cost is real
        victims = list(pending[r])
        pending[r].clear()
        free_at[r] = now
        for idx, k, c, arr in victims:
            # the work was never completed: release it from the dead replica
            # and push it back through the policy, which re-decides under
            # the live mask (train/failover.py's drain-and-redistribute)
            ledger.release(r, c)
            r2 = scheduler.route(k, c)
            requeued += 1
            hist[r] -= 1
            hist[r2] += 1
            if not streaming:
                assign[idx] = r2
            fanout[k] = fanout.get(k, 0) | (1 << int(r2))
            if queue_bound is not None and len(pending[r2]) >= queue_bound:
                scheduler.complete(r2, c)  # backpressure: overflow is shed
                if not streaming:
                    shed_mask[idx] = True
                shed += 1
                continue
            cache_insert(r2, k)  # the retry's service warms the new replica
            enqueue(idx, k, c, now, r2, arr)
            peak = max(peak, float(scheduler.loads[r2]))

    def on_revive(now: float, r: int) -> None:
        ledger.revive(r)
        free_at[r] = max(float(free_at[r]), now)

    def advance(now: float) -> None:
        """Deliver completions and fire control events with time <= now, in
        global time order (a kill must not requeue work that finished
        before it)."""
        nonlocal completed, makespan, lat_seen
        while heap or ctrl:
            t_fin = heap[0][0] if heap else np.inf
            t_ctl = ctrl[0][0] if ctrl else np.inf
            if min(t_fin, t_ctl) > now:
                return
            if t_fin <= t_ctl:
                fin, r, g, c, idx, arr = heapq.heappop(heap)
                if g != gen[r]:
                    continue  # completion of a since-killed replica
                scheduler.complete(r, c)
                completed += 1
                makespan = max(makespan, fin)
                if streaming:
                    if len(lat_res) < lat_cap:
                        lat_res.append(fin - arr)
                    else:
                        j = int(lat_rng.integers(0, lat_seen + 1))
                        if j < lat_cap:
                            lat_res[j] = fin - arr
                    lat_seen += 1
                else:
                    latency[idx] = fin - arr
                pending[r].popleft()  # heap order == per-replica FIFO order
            else:
                t, kind, r = ctrl.popleft()
                (on_kill if kind == 0 else on_revive)(t, r)

    def autoscale(i: int, t: float) -> None:
        nonlocal last_scale
        a = autoscaler
        if i % a.check_every or i - last_scale <= a.cooldown:
            return
        live = ledger.alive & eligible
        n_live = int(live.sum())
        cap_live = float(n_live) if rates is None else float(rates[live].sum())
        signal = float(scheduler.loads[live].sum()) / (cap_live * mean_cost)
        if signal > a.high and n_live < a.max_replicas:
            r = int(np.flatnonzero(~ledger.alive & eligible)[0])
            on_revive(t, r)  # lowest dead id: active set stays a prefix
            scale_events.append((t, 1, r))
            last_scale = i
        elif signal < a.low and n_live > a.min_replicas:
            r = int(np.flatnonzero(live)[-1])
            on_kill(t, r)  # highest live id: drains + requeues its work
            scale_events.append((t, -1, r))
            last_scale = i

    i = -1
    for chunk_keys in chunk_iter:
        chunk_keys = np.asarray(chunk_keys).reshape(-1)
        for kv in chunk_keys:
            i += 1
            t = i * dt
            advance(t)
            if autoscaler is not None:
                autoscale(i, t)
            k = int(kv)
            c = 1.0 if streaming else float(costs[i])
            r = scheduler.route(k, c)
            hist[r] += 1
            if not streaming:
                assign[i] = r
            if queue_bound is not None and len(pending[r]) >= queue_bound:
                # queue-based load leveling: the replica's bound is hit, shed
                # the request (ledger sees acquire+release, loads stay
                # truthful)
                scheduler.complete(r, c)
                if not streaming:
                    shed_mask[i] = True
                shed += 1
            else:
                if k in caches[r]:
                    hit_count += 1
                    if not streaming:
                        hit[i] = True
                cache_insert(r, k)
                enqueue(i, k, c, t, r, t)
                fanout[k] = fanout.get(k, 0) | (1 << int(r))
                # only replica r's load grew this arrival, so tracking it
                # keeps the true all-time peak at O(1) per request
                peak = max(peak, float(scheduler.loads[r]))
            if i % sample_every == 0:
                if streaming and i:
                    # online routed-balance checkpoint: I(t) of the live
                    # histogram (requeues already folded in); dividing the
                    # mean by final m below mirrors avg_imbalance_fraction,
                    # just with online checkpoints instead of the
                    # retrospective prefix series
                    hist_samples.append(float(hist.max() - hist.mean()))
                ld = scheduler.loads
                rt = rates
                live = ledger.live_mask() if ledger is not None else None
                if live is not None and not live.all():
                    ld = ld[live]  # dead replicas are capacity, not headroom
                    rt = None if rates is None else rates[live]
                # skip the warmup prefix: with < n requests ever routed the
                # fraction is ~(1 - 1/n) for ANY policy (one outstanding
                # request is "imbalanced" by construction), a measurement
                # artifact that would bias well-balanced policies' reported
                # values.
                if i >= n:
                    out_total = float(ld.sum())
                    if rt is not None:
                        # capacity-normalized balance (arXiv 1705.09073);
                        # the relative fraction is scale-invariant, so
                        # uniform capacities reproduce the unweighted
                        # samples exactly
                        ld = ld / rt
                    sample_ts.append(t)
                    samples_out.append(out_total)
                    samples.append(
                        (float(ld.max()) - float(ld.mean()))
                        / max(float(ld.sum()), 1.0)
                    )
    m = i + 1  # streaming: now known; array mode: unchanged

    advance(np.inf)  # drain: everything admitted eventually completes

    if ledger is not None:
        residual = float(np.abs(ledger.loads).sum())
        if residual > 1e-6:
            raise RuntimeError(
                f"ledger did not drain to zero (residual {residual:.3g}): "
                "acquire/release accounting lost a completion"
            )

    if streaming:
        done = np.asarray(sorted(lat_res), dtype=np.float64)
        latency = done
        assign = np.empty(0, dtype=np.int32)
        hit = np.zeros(0, dtype=bool)
        shed_mask = np.zeros(0, dtype=bool)
        # online checkpointed estimate of the paper's Mean_t I(t)/m; array
        # mode keeps the exact retrospective series for bit-compatibility
        assign_imb = (
            float(np.mean(hist_samples)) / m if hist_samples
            else (float(hist.max() - hist.mean()) / m if m else 0.0)
        )
    else:
        done = latency[~np.isnan(latency)]
        assign_imb = avg_imbalance_fraction(assign, n) if m else 0.0
    report = None
    if tenants is not None:
        report = tenant_imbalance_report(
            assign, tenants, n, slo=slo, n_checkpoints=slo_checkpoints
        )
    return SimResult(
        assign=assign,
        hit=hit,
        hit_rate=(hit_count / m) if m else 0.0,
        assign_imbalance=assign_imb,
        # nan, not 0.0: a run too short to produce post-warmup samples must
        # not masquerade as perfect balance
        outstanding_imbalance=float(np.mean(samples)) if samples
        else float("nan"),
        peak_outstanding=peak,
        session_fanout_max=max(
            (bin(v).count("1") for v in fanout.values()), default=0
        ),
        completed=completed,
        makespan=makespan,
        latency=latency,
        latency_p50=_percentile(done, 50.0),
        latency_p99=_percentile(done, 99.0),
        latency_p999=_percentile(done, 99.9),
        shed=shed,
        shed_mask=shed_mask,
        requeued=requeued,
        sample_times=np.asarray(sample_ts, dtype=np.float64),
        sample_imbalance=np.asarray(samples, dtype=np.float64),
        sample_outstanding=np.asarray(samples_out, dtype=np.float64),
        tenant_report=report,
        scale_events=scale_events,
        assign_hist=hist,
    )
