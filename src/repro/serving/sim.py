"""Discrete-event serving simulator: the closed request/completion loop.

The schedulers' load accounting only means "outstanding work" if something
ever calls ``complete()`` — this module is that something.  Requests arrive
at a fixed rate (``utilization`` of aggregate replica capacity), each replica
is a FIFO queue serving one request at a time, and a completion event fires
``scheduler.complete(replica, cost)`` before the next arrival is routed, so
the scheduler's ledger tracks genuinely outstanding work (the metric
``launch/serve.py`` used to mislabel).

On top of the queueing model sits the serving-edge tradeoff the paper's §7
cluster story implies (DESIGN.md §8): each replica keeps an LRU **prefix
cache** over session keys (capacity ``cache_capacity``); a request hits iff
its session key is resident on the replica it lands on.  Sticky KG maximizes
hit-rate and ruins balance under skew; round-robin is the opposite corner;
PoTC/W-Choices trade between them.  multi-tenant streams additionally get
per-tenant SLO accounting via core.metrics.tenant_imbalance_report.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import OrderedDict
from typing import Optional

import numpy as np

from repro.core.metrics import avg_imbalance_fraction, tenant_imbalance_report

__all__ = ["SimResult", "simulate_serving"]


@dataclasses.dataclass
class SimResult:
    """Everything the benches and demos report (assign/hit are per-request
    arrays, the rest scalar summaries)."""

    assign: np.ndarray          # (m,) replica per request
    hit: np.ndarray             # (m,) bool prefix-cache hit per request
    hit_rate: float             # mean(hit)
    assign_imbalance: float     # avg imbalance fraction of routed work
    outstanding_imbalance: float  # mean I(t)/outstanding over post-warmup
    #   samples; nan when the run is too short (< n_replicas requests) to
    #   produce any
    peak_outstanding: float     # max outstanding work on any replica, ever
    session_fanout_max: int     # worst-case replicas touched by one session
    completed: int              # completions delivered to the scheduler
    makespan: float             # last completion time
    tenant_report: Optional[dict] = None


def simulate_serving(
    scheduler,
    keys,
    costs=None,
    tenants=None,
    *,
    utilization: float = 0.7,
    cache_capacity: int = 64,
    slo: float = 0.05,
    sample_every: Optional[int] = None,
    slo_checkpoints: int = 50,
) -> SimResult:
    """Drive ``scheduler`` (route/complete/loads) through a request stream.

    keys (m,) are session ids; costs (m,) are service times (default 1.0).
    Arrivals are evenly spaced so offered load is ``utilization`` of the
    aggregate service rate; replicas serve FIFO at unit rate, and every
    completion with finish time <= the current arrival is delivered via
    ``scheduler.complete`` before the arrival is routed.  After the last
    arrival the queue drains fully, so a correct scheduler ends with ~zero
    outstanding load (asserted in tests, not here).

    With ``tenants`` given, the result carries a per-tenant SLO report
    (core.metrics.tenant_imbalance_report at threshold ``slo``).
    """
    keys = np.asarray(keys).reshape(-1)
    m = len(keys)
    n = len(scheduler.loads)
    if costs is None:
        costs = np.ones(m, dtype=np.float64)
    else:
        costs = np.asarray(costs, dtype=np.float64).reshape(-1)
        if len(costs) != m:
            raise ValueError(f"costs length {len(costs)} != {m}")
    if not 0.0 < utilization:
        raise ValueError(f"utilization must be positive, got {utilization}")
    dt = float(costs.mean()) / (utilization * n)
    if sample_every is None:
        sample_every = max(m // 256, 1)

    heap: list[tuple[float, int, float]] = []  # (finish, replica, cost)
    free_at = np.zeros(n, dtype=np.float64)
    caches = [OrderedDict() for _ in range(n)]
    assign = np.empty(m, dtype=np.int32)
    hit = np.zeros(m, dtype=bool)
    fanout: dict[int, set] = {}
    samples: list[float] = []
    peak = 0.0
    completed = 0
    makespan = 0.0

    for i in range(m):
        t = i * dt
        while heap and heap[0][0] <= t:
            fin, r, c = heapq.heappop(heap)
            scheduler.complete(r, c)
            completed += 1
            makespan = max(makespan, fin)
        k = int(keys[i])
        c = float(costs[i])
        r = scheduler.route(k, c)
        assign[i] = r
        cache = caches[r]
        if k in cache:
            hit[i] = True
            cache.move_to_end(k)
        else:
            cache[k] = True
            if len(cache) > cache_capacity:
                cache.popitem(last=False)
        start = max(t, float(free_at[r]))
        free_at[r] = start + c
        heapq.heappush(heap, (start + c, r, c))
        fanout.setdefault(k, set()).add(int(r))
        # only replica r's load grew this arrival, so tracking it keeps the
        # true all-time peak at O(1) per request
        peak = max(peak, float(scheduler.loads[r]))
        if i % sample_every == 0:
            ld = scheduler.loads
            # skip the warmup prefix: with < n requests ever routed the
            # fraction is ~(1 - 1/n) for ANY policy (one outstanding request
            # is "imbalanced" by construction), a measurement artifact that
            # would bias well-balanced policies' reported values.
            if i >= n:
                samples.append(
                    (float(ld.max()) - float(ld.mean()))
                    / max(float(ld.sum()), 1.0)
                )

    while heap:  # drain: everything routed eventually completes
        fin, r, c = heapq.heappop(heap)
        scheduler.complete(r, c)
        completed += 1
        makespan = max(makespan, fin)

    report = None
    if tenants is not None:
        report = tenant_imbalance_report(
            assign, tenants, n, slo=slo, n_checkpoints=slo_checkpoints
        )
    return SimResult(
        assign=assign,
        hit=hit,
        hit_rate=float(hit.mean()) if m else 0.0,
        assign_imbalance=avg_imbalance_fraction(assign, n) if m else 0.0,
        # nan, not 0.0: a run too short to produce post-warmup samples must
        # not masquerade as perfect balance
        outstanding_imbalance=float(np.mean(samples)) if samples
        else float("nan"),
        peak_outstanding=peak,
        session_fanout_max=max((len(v) for v in fanout.values()), default=0),
        completed=completed,
        makespan=makespan,
        tenant_report=report,
    )
