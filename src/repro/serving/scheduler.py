"""Request -> replica schedulers (the paper's algorithm at the cluster edge).

PoTCScheduler is PKG verbatim: each *frontend* keeps only a local estimate of
outstanding work per replica; a request's key (e.g. prefix-cache/session id)
hashes to d=2 candidate replicas; the less-loaded one wins.  Keys therefore
hit at most 2 replicas (prefix caches stay warm ~2-way) while load stays
balanced under key skew — the serving analogue of key splitting.

Baselines: KGScheduler (sticky hashing — hot sessions overload one replica)
and RoundRobinScheduler (balanced but 0% cache affinity).
"""
from __future__ import annotations

import numpy as np

__all__ = ["PoTCScheduler", "KGScheduler", "RoundRobinScheduler"]


def _h32(x: int, seed: int) -> int:
    v = (x ^ (seed * 0x9E3779B9)) & 0xFFFFFFFF
    v = ((v ^ (v >> 16)) * 0x7FEB352D) & 0xFFFFFFFF
    v = ((v ^ (v >> 15)) * 0x846CA68B) & 0xFFFFFFFF
    return (v ^ (v >> 16)) & 0xFFFFFFFF


class PoTCScheduler:
    """Power-of-two-choices with local load estimation per frontend."""

    def __init__(self, n_replicas: int, d: int = 2, seed: int = 0):
        self.n = n_replicas
        self.d = d
        self.seed = seed
        self.loads = np.zeros(n_replicas, dtype=np.float64)  # outstanding tokens

    def route(self, key: int, cost: float = 1.0) -> int:
        cands = [_h32(key, self.seed + j) % self.n for j in range(self.d)]
        c = min(cands, key=lambda i: self.loads[i])
        self.loads[c] += cost
        return c

    def complete(self, replica: int, cost: float = 1.0) -> None:
        self.loads[replica] = max(0.0, self.loads[replica] - cost)


class KGScheduler:
    """Sticky key-hashing (single choice)."""

    def __init__(self, n_replicas: int, seed: int = 0):
        self.n, self.seed = n_replicas, seed
        self.loads = np.zeros(n_replicas, dtype=np.float64)

    def route(self, key: int, cost: float = 1.0) -> int:
        c = _h32(key, self.seed) % self.n
        self.loads[c] += cost
        return c

    def complete(self, replica: int, cost: float = 1.0) -> None:
        self.loads[replica] = max(0.0, self.loads[replica] - cost)


class RoundRobinScheduler:
    def __init__(self, n_replicas: int, seed: int = 0):
        self.n = n_replicas
        self._i = 0
        self.loads = np.zeros(n_replicas, dtype=np.float64)

    def route(self, key: int, cost: float = 1.0) -> int:
        c = self._i % self.n
        self._i += 1
        self.loads[c] += cost
        return c

    def complete(self, replica: int, cost: float = 1.0) -> None:
        self.loads[replica] = max(0.0, self.loads[replica] - cost)
