"""Request -> replica schedulers: thin adapters over core.routing.

The routing rules themselves live in the unified substrate
(core/routing.py): one RoutingPolicy per technique, one LoadLedger for load
accounting, candidates from core.hashing's SplitMix32 family (the same hash
the partitioners and kernels use).  This module only adapts a policy to the
classic per-request scheduler interface —

    r = sched.route(key, cost)     # decide + acquire
    sched.complete(r, cost)        # release (completion event)
    sched.loads                    # the ledger's outstanding-work vector

— and re-exports the four named schedulers as one-line subclasses, so
existing callers keep their constructors while the load-accounting and
hashing code exists exactly once.  Driving a fresh scheduler over a stream
with no completions is bit-identical to ``policy.route_batch`` on the same
stream (tests/test_routing.py).

PoTCScheduler is PKG verbatim at the cluster edge (paper §7): a request's
session key hashes to d=2 candidate replicas, the less-loaded wins — keys
touch <= 2 replicas (prefix caches stay warm) while load balances under
skew.  WChoicesScheduler (arXiv 1510.05714, DESIGN.md §3.3) upgrades it for
the W >> head-keys regime: a SPACESAVING tracker flags hot session ids
online and routes them to the globally least-loaded replica.  KGScheduler
(sticky hashing) and RoundRobinScheduler are the two ends of the
prefix-cache/balance tradeoff that serving.sim measures.
"""
from __future__ import annotations

from typing import Optional

from repro.core.routing import (
    KGPolicy,
    LoadLedger,
    PoTCPolicy,
    RoundRobinPolicy,
    RoutingPolicy,
    WChoicesPolicy,
)

__all__ = [
    "PolicyScheduler",
    "PoTCScheduler",
    "KGScheduler",
    "RoundRobinScheduler",
    "WChoicesScheduler",
]


class PolicyScheduler:
    """THE per-request adapter: one policy + one ledger, nothing else.

    The scheduler takes OWNERSHIP of the policy instance: construction
    reset()s its estimator state (the adapter==route_batch contract starts
    from scratch), and sharing one policy across schedulers would couple
    their routing through the shared tracker/cursor — give each scheduler
    its own instance (make_policy is cheap).

    ``capacities`` (optional (n,) non-negative per-replica speeds, arXiv
    1705.09073) lands in the ledger and reaches every decide() call: load
    comparisons become capacity-normalized (least ``load/c`` wins) and
    zero-capacity replicas are folded into the dead mask.  None keeps the
    unweighted path bit-identical; uniform capacities reproduce it exactly.
    """

    def __init__(self, policy: RoutingPolicy, strict: bool = False,
                 capacities=None):
        if not policy.per_request:
            raise ValueError(
                f"policy {policy.name!r} is batch-only (device-backed); "
                "per-request serving needs a host policy"
            )
        policy.reset()  # the adapter==route_batch contract needs fresh state
        self.policy = policy
        self.ledger = LoadLedger(policy.n, strict=strict,
                                 capacities=capacities)

    @property
    def n(self) -> int:
        return self.policy.n

    @property
    def loads(self):
        return self.ledger.loads

    def route(self, key: int, cost: float = 1.0) -> int:
        c = self.policy.decide(
            int(key), self.ledger.loads, self.ledger.live_mask(),
            capacities=self.ledger.capacities,
        )
        self.ledger.acquire(c, cost)
        return c

    def complete(self, replica: int, cost: float = 1.0) -> None:
        self.ledger.release(replica, cost)

    def kill(self, replica: int) -> None:
        """Mark a replica dead; subsequent routes avoid it (the simulator
        additionally requeues its pending work — see serving.sim)."""
        self.ledger.kill(replica)

    def revive(self, replica: int) -> None:
        self.ledger.revive(replica)


class PoTCScheduler(PolicyScheduler):
    """Power-of-two-choices with local load estimation per frontend."""

    def __init__(self, n_replicas: int, d: int = 2, seed: int = 0,
                 capacities=None):
        super().__init__(PoTCPolicy(n_replicas, d=d, seed=seed),
                         capacities=capacities)
        self.d = self.policy.d
        self.seed = seed


class KGScheduler(PolicyScheduler):
    """Sticky key-hashing (single choice)."""

    def __init__(self, n_replicas: int, seed: int = 0, capacities=None):
        super().__init__(KGPolicy(n_replicas, seed=seed),
                         capacities=capacities)
        self.seed = seed


class RoundRobinScheduler(PolicyScheduler):
    """Cyclic routing; the seed sets a scrambled start offset."""

    def __init__(self, n_replicas: int, seed: int = 0, capacities=None):
        super().__init__(RoundRobinPolicy(n_replicas, seed=seed),
                         capacities=capacities)
        self.seed = seed


class WChoicesScheduler(PolicyScheduler):
    """W-Choices: hot session ids may route to any replica; cold sessions
    keep PoTC's d-candidate step and <= d replica fanout.

    ``capacity`` sizes the SPACESAVING tracker (how many hot session ids it
    can hold); ``capacities`` are the per-replica speeds — unrelated knobs
    that happen to share a stem.
    """

    def __init__(self, n_replicas: int, d: int = 2, seed: int = 0,
                 capacity: int = 256, theta: Optional[float] = None,
                 min_count: int = 8, capacities=None):
        super().__init__(
            WChoicesPolicy(
                n_replicas, d=d, seed=seed, capacity=capacity, theta=theta,
                min_count=min_count,
            ),
            capacities=capacities,
        )
        self.d = self.policy.d
        self.seed = seed

    @property
    def tracker(self):
        return self.policy.tracker
