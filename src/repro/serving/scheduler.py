"""Request -> replica schedulers (the paper's algorithm at the cluster edge).

PoTCScheduler is PKG verbatim: each *frontend* keeps only a local estimate of
outstanding work per replica; a request's key (e.g. prefix-cache/session id)
hashes to d=2 candidate replicas; the less-loaded one wins.  Keys therefore
hit at most 2 replicas (prefix caches stay warm ~2-way) while load stays
balanced under key skew — the serving analogue of key splitting.

Baselines: KGScheduler (sticky hashing — hot sessions overload one replica)
and RoundRobinScheduler (balanced but 0% cache affinity).

WChoicesScheduler is the W-Choices upgrade (arXiv 1510.05714, DESIGN.md
SS3.3): a SPACESAVING tracker flags hot session ids online, and hot requests
may route to ANY replica (global least-loaded) while cold sessions keep the
d=2 affinity guarantee.  This is the regime where replicas outnumber hot
sessions and two choices per hot key are no longer enough.
"""
from __future__ import annotations

import numpy as np

from repro.core.estimation import SpaceSavingTracker, head_threshold

__all__ = [
    "PoTCScheduler",
    "KGScheduler",
    "RoundRobinScheduler",
    "WChoicesScheduler",
]


def _h32(x: int, seed: int) -> int:
    v = (x ^ (seed * 0x9E3779B9)) & 0xFFFFFFFF
    v = ((v ^ (v >> 16)) * 0x7FEB352D) & 0xFFFFFFFF
    v = ((v ^ (v >> 15)) * 0x846CA68B) & 0xFFFFFFFF
    return (v ^ (v >> 16)) & 0xFFFFFFFF


class PoTCScheduler:
    """Power-of-two-choices with local load estimation per frontend."""

    def __init__(self, n_replicas: int, d: int = 2, seed: int = 0):
        self.n = n_replicas
        self.d = d
        self.seed = seed
        self.loads = np.zeros(n_replicas, dtype=np.float64)  # outstanding tokens

    def route(self, key: int, cost: float = 1.0) -> int:
        cands = [_h32(key, self.seed + j) % self.n for j in range(self.d)]
        c = min(cands, key=lambda i: self.loads[i])
        self.loads[c] += cost
        return c

    def complete(self, replica: int, cost: float = 1.0) -> None:
        self.loads[replica] = max(0.0, self.loads[replica] - cost)


class KGScheduler:
    """Sticky key-hashing (single choice)."""

    def __init__(self, n_replicas: int, seed: int = 0):
        self.n, self.seed = n_replicas, seed
        self.loads = np.zeros(n_replicas, dtype=np.float64)

    def route(self, key: int, cost: float = 1.0) -> int:
        c = _h32(key, self.seed) % self.n
        self.loads[c] += cost
        return c

    def complete(self, replica: int, cost: float = 1.0) -> None:
        self.loads[replica] = max(0.0, self.loads[replica] - cost)


class WChoicesScheduler(PoTCScheduler):
    """W-Choices: hot session ids may route to any replica.

    Cold keys behave exactly like PoTCScheduler (d candidates, least loaded
    wins, <= d replicas per key).  A key becomes hot once its estimated
    request fraction reaches `theta` (default d/n_replicas, the balanceability
    limit); from then on it goes to the globally least-loaded replica.
    """

    def __init__(self, n_replicas: int, d: int = 2, seed: int = 0,
                 capacity: int = 256, theta: float | None = None,
                 min_count: int = 8):
        super().__init__(n_replicas, d=d, seed=seed)
        self.theta = head_threshold(n_replicas, d) if theta is None else theta
        self.min_count = min_count
        self.tracker = SpaceSavingTracker(capacity)

    def route(self, key: int, cost: float = 1.0) -> int:
        self.tracker.offer(key)
        if self.tracker.is_head(key, self.theta, min_count=self.min_count):
            c = int(np.argmin(self.loads))
            self.loads[c] += cost
            return c
        return super().route(key, cost)


class RoundRobinScheduler:
    def __init__(self, n_replicas: int, seed: int = 0):
        self.n = n_replicas
        self._i = 0
        self.loads = np.zeros(n_replicas, dtype=np.float64)

    def route(self, key: int, cost: float = 1.0) -> int:
        c = self._i % self.n
        self._i += 1
        self.loads[c] += cost
        return c

    def complete(self, replica: int, cost: float = 1.0) -> None:
        self.loads[replica] = max(0.0, self.loads[replica] - cost)
