"""h2o-danube-1.8b [dense]: 24L d=2560 32H (GQA kv=8) d_ff=6912 vocab=32000.

llama + mistral mix: sliding-window attention (4096) on every layer, SwiGLU.
hd = 80 (d_model / n_heads). [arXiv:2401.16818]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="h2o-danube-1.8b",
        family="dense",
        n_layers=24,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        head_dim=80,
        d_ff=6912,
        vocab_size=32000,
        attn_pattern=("local",),
        window=4096,
        rope_base_local=10_000.0,
        mlp="swiglu",
        tie_embeddings=False,
    )
)
