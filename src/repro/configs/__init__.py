"""Arch registry: importing this package registers all assigned architectures."""
import dataclasses

from repro.configs.base import (
    ARCH_REGISTRY,
    SHAPES,
    ModelConfig,
    ShapeConfig,
    TrainConfig,
    get_config,
    register,
)

# one module per assigned architecture (registration side effect)
from repro.configs import (  # noqa: F401
    chameleon_34b,
    deepseek_67b,
    gemma3_4b,
    h2o_danube_1_8b,
    mamba2_1_3b,
    mixtral_8x7b,
    musicgen_medium,
    olmoe_1b_7b,
    qwen2_5_3b,
    recurrentgemma_2b,
)

ALL_ARCHS = tuple(sorted(ARCH_REGISTRY))

# long_500k requires sub-quadratic attention / bounded state (DESIGN.md §5):
LONG_CONTEXT_ARCHS = frozenset(
    {"gemma3-4b", "h2o-danube-1.8b", "recurrentgemma-2b", "mixtral-8x7b", "mamba2-1.3b"}
)


def shapes_for(arch: str):
    """The assigned shape cells for an arch (skips long_500k when quadratic)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in LONG_CONTEXT_ARCHS:
        names.append("long_500k")
    return [SHAPES[n] for n in names]


def make_tiny(cfg: ModelConfig, seq_len: int = 64) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests (per-arch, see spec f)."""
    pat = cfg.attn_pattern
    n_layers = 2 * len(pat) + (1 if cfg.n_remainder else 0)
    kv = max(1, (4 * cfg.n_kv_heads) // max(cfg.n_heads, 1)) if cfg.n_kv_heads else 0
    return dataclasses.replace(
        cfg,
        name=f"tiny-{cfg.name}",
        n_layers=n_layers,
        d_model=64,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=kv,
        head_dim=16 if cfg.n_heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=512,
        window=min(cfg.window, 32) if cfg.window else 0,
        rnn_width=64 if cfg.rnn_width else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_headdim=16 if cfg.ssm_state else 64,
        ssm_chunk=16,
        n_experts=8 if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        pkg_block=16,
        attn_q_block=32,
        vocab_pad_multiple=16,
    )
