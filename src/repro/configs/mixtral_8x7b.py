"""mixtral-8x7b [moe]: 32L d=4096 32H (GQA kv=8) d_ff=14336/expert vocab=32000.

8 experts top-2 — the exact two-choice shape of the paper; PKG-PoTC routing
(router="pkg_potc") is a drop-in replacement for aux-loss balancing here, and
the adaptive modes (router="d_choices"/"w_choices", DESIGN.md §3.3) widen hot
experts' tokens to router_d_max candidates / spill them globally.
Sliding-window attention 4096. [arXiv:2401.04088]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="mixtral-8x7b",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=32000,
        attn_pattern=("local",),
        window=4096,
        rope_base_local=1_000_000.0,
        mlp="swiglu",
        tie_embeddings=False,
        n_experts=8,
        top_k=2,
        router="topk_aux",
        capacity_factor=1.25,
        router_d_max=4,  # d_choices ceiling: top-4 ranked experts per slot
    )
)
