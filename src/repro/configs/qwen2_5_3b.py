"""qwen2.5-3b [dense]: 36L d=2048 16H (GQA kv=2) d_ff=11008 vocab=151936.

Full attention, QKV bias, SwiGLU, tied embeddings. [hf:Qwen/Qwen2.5-3B]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen2.5-3b",
        family="dense",
        n_layers=36,
        d_model=2048,
        n_heads=16,
        n_kv_heads=2,
        head_dim=128,
        d_ff=11008,
        vocab_size=151936,
        attn_pattern=("global",),
        rope_base_global=1_000_000.0,
        qkv_bias=True,
        mlp="swiglu",
        tie_embeddings=True,
    )
)
