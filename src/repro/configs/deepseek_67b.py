"""deepseek-67b [dense]: 95L d=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.

llama-architecture (pre-norm RMSNorm, SwiGLU, RoPE), untied embeddings.
[arXiv:2401.02954]

Also registers deepseek-moe-16b, the family's fine-grained MoE sibling
(64 routed experts, top-6, narrow d_ff per expert — the many-small-experts
regime where router skew is most damaging and the adaptive d_choices /
w_choices modes have the most headroom; shared experts are omitted, routed
path only).  [arXiv:2401.06066]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="deepseek-67b",
        family="dense",
        n_layers=95,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=22016,
        vocab_size=102400,
        attn_pattern=("global",),
        rope_base_global=10_000.0,
        mlp="swiglu",
        tie_embeddings=False,
    )
)

MOE_CONFIG = register(
    ModelConfig(
        name="deepseek-moe-16b",
        family="moe",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab_size=102400,
        attn_pattern=("global",),
        rope_base_global=10_000.0,
        mlp="swiglu",
        tie_embeddings=False,
        n_experts=64,
        top_k=6,
        router="topk_aux",
        capacity_factor=1.25,
        router_d_max=4,  # 6 slots x 4 candidates = 24 ranked experts of 64
    )
)
