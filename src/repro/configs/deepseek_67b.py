"""deepseek-67b [dense]: 95L d=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.

llama-architecture (pre-norm RMSNorm, SwiGLU, RoPE), untied embeddings.
[arXiv:2401.02954]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="deepseek-67b",
        family="dense",
        n_layers=95,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=22016,
        vocab_size=102400,
        attn_pattern=("global",),
        rope_base_global=10_000.0,
        mlp="swiglu",
        tie_embeddings=False,
    )
)
