"""chameleon-34b [vlm]: 48L d=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.

Early-fusion multimodal: VQ image tokens and text share one 65536 vocab, so
the backbone is a plain decoder over token ids (the VQ-VAE tokenizer is a
stub per the assignment — image inputs arrive as token ids).  qk-norm per the
paper for training stability. [arXiv:2405.09818]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="chameleon-34b",
        family="vlm",
        n_layers=48,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=22016,
        vocab_size=65536,
        attn_pattern=("global",),
        qk_norm=True,
        mlp="swiglu",
        tie_embeddings=False,
    )
)
