"""mamba2-1.3b [ssm]: 48L d=2048, attention-free SSD, vocab=50280.

State-space duality blocks: d_inner = 2*2048, headdim 64 (64 SSD heads),
d_state 128, 1 group, conv width 4; no MLP (block is gated internally).
vocab padded to 50432 for 16-way TP. [arXiv:2405.21060]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=0,
        n_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=50280,
        attn_pattern=("ssd",),
        ssm_expand=2,
        ssm_state=128,
        ssm_headdim=64,
        ssm_ngroups=1,
        ssm_chunk=256,
        conv_width=4,
        tie_embeddings=True,
    )
)
