"""musicgen-medium [audio]: 48L d=1536 24H (MHA kv=24) d_ff=6144 vocab=2048.

Decoder-only over EnCodec tokens (4 codebooks, delay pattern). The EnCodec
frontend is a STUB per the assignment: input_specs() provides precomputed
frame embeddings (B,S,d); the model adds 4 codebook output heads.
[arXiv:2306.05284]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="musicgen-medium",
        family="audio",
        n_layers=48,
        d_model=1536,
        n_heads=24,
        n_kv_heads=24,
        head_dim=64,
        d_ff=6144,
        vocab_size=2048,
        attn_pattern=("global",),
        mlp="geglu",
        tie_embeddings=False,
        frontend="audio_stub",
        n_io_heads=4,
    )
)
