"""recurrentgemma-2b [hybrid]: 26L d=2560 10H (MQA kv=1) d_ff=7680 vocab=256000.

Griffin architecture: RG-LRU + local attention in a 2:1 pattern
(rglru, rglru, local-attn), window 2048, rnn width 2560, GeGLU, hd=256.
[arXiv:2402.19427]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256000,
        attn_pattern=("rglru", "rglru", "local"),
        window=2048,
        rope_base_local=10_000.0,
        rnn_width=2560,
        mlp="geglu",
        tie_embeddings=True,
    )
)
