"""Model / train / mesh configuration dataclasses and the arch registry."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "TrainConfig", "ShapeConfig", "SHAPES", "register", "get_config", "ARCH_REGISTRY"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # layer pattern: cycle of {"global","local","rglru","ssd"}
    attn_pattern: Tuple[str, ...] = ("global",)
    window: int = 0  # sliding window for "local" layers
    rope_base_local: float = 10_000.0
    rope_base_global: float = 10_000.0
    qkv_bias: bool = False
    qk_norm: bool = False
    logit_softcap: float = 0.0
    mlp: str = "swiglu"  # swiglu | geglu
    tie_embeddings: bool = True
    # MoE
    n_experts: int = 0
    top_k: int = 0
    router: str = "topk_aux"  # topk_aux | pkg_potc | d_choices | w_choices
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01
    pkg_block: int = 256  # token block for PKG-PoTC batch-greedy routing
    # adaptive routers (d_choices/w_choices): candidate-lane ceiling and
    # SPACESAVING expert-popularity summary size (0 -> n_experts, exact)
    router_d_max: int = 4
    router_ss_capacity: int = 0
    # SSM (mamba2)
    ssm_expand: int = 2
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_chunk: int = 256
    conv_width: int = 4
    # RG-LRU (recurrentgemma)
    rnn_width: int = 0
    # IO frontend
    frontend: str = "tokens"  # tokens | audio_stub (precomputed embeddings)
    n_io_heads: int = 1  # musicgen: 4 codebook output heads
    # numerics / compute
    attn_q_block: int = 512  # q-chunk for memory-bounded attention
    vocab_pad_multiple: int = 256
    # scan-over-superblocks (compact HLO) vs unrolled layers (exact
    # cost_analysis — XLA counts loop bodies once; see launch/dryrun.py)
    scan_layers: bool = True

    @property
    def vocab_padded(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_state else 0

    def layer_kinds(self) -> Tuple[str, ...]:
        p = self.attn_pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    @property
    def n_superblocks(self) -> int:
        if not self.scan_layers:
            return 0
        return self.n_layers // len(self.attn_pattern)

    @property
    def n_remainder(self) -> int:
        return self.n_layers - self.n_superblocks * len(self.attn_pattern)

    def param_count(self) -> int:
        """Total parameters N (for MODEL_FLOPS = 6*N*D roofline)."""
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        return _param_count(self, active_only=True)


def _param_count(cfg: ModelConfig, active_only: bool) -> int:
    d, hd = cfg.d_model, cfg.head_dim
    n = cfg.vocab_padded * d  # embed
    if not cfg.tie_embeddings:
        n += cfg.vocab_padded * d * cfg.n_io_heads
    per_layer = {}
    for kind in set(cfg.layer_kinds()):
        if kind in ("global", "local"):
            attn = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d
        elif kind == "rglru":
            w = cfg.rnn_width
            attn = 2 * d * w + w * cfg.conv_width + 2 * w * w + w * d  # in-proj x2, conv, gates, out
        elif kind == "ssd":
            di, g, s, h = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_heads
            attn = d * (2 * di + 2 * g * s + h) + (di + 2 * g * s) * cfg.conv_width + di * d + h * 2
        else:
            raise ValueError(kind)
        if kind == "ssd":
            ffn = 0
        elif cfg.n_experts:
            e = cfg.top_k if active_only else cfg.n_experts
            ffn = e * 3 * d * cfg.d_ff + d * cfg.n_experts  # experts + router
        else:
            ffn = 3 * d * cfg.d_ff
        per_layer[kind] = attn + ffn + 2 * d  # + norms
    return n + sum(per_layer[k] for k in cfg.layer_kinds())


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int
    microbatches: int = 1  # gradient accumulation steps (train only)


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256, microbatches=1),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    z_loss: float = 1e-4
    schedule: str = "cosine"  # cosine | linear | const
    microbatches: int = 1
    remat: bool = True
    grad_compression: str = "none"  # none | int8_ef (explicit-DP path)
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    seed: int = 0


ARCH_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    ARCH_REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # import registers all arch modules on first use
    from repro import configs as _c  # noqa: F401

    if name not in ARCH_REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCH_REGISTRY)}")
    return ARCH_REGISTRY[name]
