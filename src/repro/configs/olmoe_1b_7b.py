"""olmoe-1b-7b [moe]: 16L d=2048 16H (MHA kv=16) d_ff=1024/expert vocab=50304.

64 experts, top-8 routing, qk-norm, full attention, SwiGLU experts.
PKG-PoTC routing selectable (router="pkg_potc"), as are the skew-adaptive
modes (router="d_choices"/"w_choices") — see DESIGN.md §3.2/§3.3.
[arXiv:2409.02060]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1024,
        vocab_size=50304,
        attn_pattern=("global",),
        qk_norm=True,
        mlp="swiglu",
        tie_embeddings=False,
        n_experts=64,
        top_k=8,
        router="topk_aux",
        capacity_factor=1.25,
        router_d_max=4,  # 8 slots x 4 candidates = 32 ranked experts of 64
    )
)
