"""gemma3-4b [dense]: 34L d=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.

5:1 local:global attention interleave, SWA window 1024 on local layers,
RoPE base 10k local / 1M global, qk-norm, GeGLU, tied embeddings, hd=256.
[hf:google/gemma-3-*-pt]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="gemma3-4b",
        family="dense",
        n_layers=34,
        d_model=2560,
        n_heads=8,
        n_kv_heads=4,
        head_dim=256,
        d_ff=10240,
        vocab_size=262144,
        attn_pattern=("local", "local", "local", "local", "local", "global"),
        window=1024,
        rope_base_local=10_000.0,
        rope_base_global=1_000_000.0,
        qk_norm=True,
        mlp="geglu",
        tie_embeddings=True,
    )
)
