"""Fault-tolerance harness: checkpoint/restart with exact data replay.

`TrainingHarness.run` drives (train_step, pipeline) to a target step,
checkpointing every `checkpoint_every` steps (async, atomic).  Failures —
injected (`SimulatedFailure`), NaN losses, or real preemptions — unwind to
the caller, which re-creates the harness and calls `run` again: it resumes
from the latest checkpoint, restores params/opt/data-iterator state, and
replays the stream deterministically, so a crash at step k never repeats or
skips a batch.

Straggler/elastic notes (DESIGN.md §6): steps are synchronous SPMD, so
per-step stragglers are bounded by the PKG-balanced input edge and the
bounded expert capacities; elastic restarts re-shard the checkpoint onto the
new mesh via CheckpointManager.restore(shardings=...).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager

__all__ = ["TrainingHarness", "SimulatedFailure"]


class SimulatedFailure(RuntimeError):
    """Injected node failure (tests / chaos drills)."""


@dataclasses.dataclass
class TrainingHarness:
    train_step: Callable  # (params, opt, batch, step) -> (params, opt, metrics)
    pipeline: object  # PKGDataPipeline-like (iterator + state()/load_state())
    manager: CheckpointManager
    checkpoint_every: int = 50
    fail_at_step: Optional[int] = None  # inject a failure once at this step

    def run(self, params, opt_state, target_step: int, log_every: int = 0):
        """Run to target_step, resuming from the latest checkpoint if any."""
        start = 0
        latest = self.manager.latest_step()
        if latest is not None:
            blob = self.manager.restore(
                {"params": params, "opt": opt_state, "data": self.pipeline.state()},
                step=latest,
            )
            params, opt_state = blob["params"], blob["opt"]
            self.pipeline.load_state(blob["data"])
            start = latest

        history = []
        try:
            for step in range(start, target_step):
                if self.fail_at_step is not None and step == self.fail_at_step:
                    self.fail_at_step = None  # fail exactly once
                    raise SimulatedFailure(f"injected failure at step {step}")
                batch = next(self.pipeline)
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                params, opt_state, metrics = self.train_step(
                    params, opt_state, batch, jnp.int32(step)
                )
                loss = float(metrics["loss"])
                if not np.isfinite(loss):
                    # NaN guard: restart from the last good checkpoint
                    raise SimulatedFailure(f"non-finite loss at step {step}")
                history.append(loss)
                if log_every and (step + 1) % log_every == 0:
                    print(f"step {step+1}: loss={loss:.4f}")
                if (step + 1) % self.checkpoint_every == 0 or step + 1 == target_step:
                    self.manager.save(
                        step + 1,
                        {"params": params, "opt": opt_state, "data": self.pipeline.state()},
                        blocking=False,
                    )
        finally:
            # A failure must not outrun the async save it will restart from:
            # commit any in-flight checkpoint before unwinding to the caller.
            self.manager.wait()
        return params, opt_state, history
