from repro.train.loop import make_train_step, make_dp_train_step, init_train_state
from repro.train.failover import TrainingHarness, SimulatedFailure
