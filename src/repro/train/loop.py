"""Training step factories: jit/pjit step with microbatch gradient
accumulation, global-norm clip, AdamW; plus an explicit-DP variant with
int8+error-feedback compressed cross-pod gradient reduction (shard_map).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models.transformer import loss_fn
from repro.optim.adamw import adamw_init, adamw_update, clip_by_global_norm
from repro.optim.schedules import make_schedule
from repro.parallel.compression import compressed_psum_mean, ef_init

__all__ = ["init_train_state", "make_train_step", "make_dp_train_step"]


def _id_sh(name, x):
    return x


def init_train_state(cfg, key, param_dtype=jnp.float32):
    from repro.models.transformer import init_params

    params = init_params(cfg, key, param_dtype)
    return params, adamw_init(params)


def make_train_step(
    cfg,
    tcfg,
    sh: Callable = _id_sh,
    microbatches: Optional[int] = None,
    grad_shardings=None,
):
    """Returns train_step(params, opt_state, batch, step) -> (p, o, metrics).

    Mixed precision: fp32 master params are cast to bf16 *once per step,
    before use*, so FSDP all-gathers and gradient reductions move bf16 on the
    wire (2x fewer collective bytes than gathering fp32 masters).
    `grad_shardings` (optional, == param shardings) constrains the gradient
    tree so XLA emits reduce-scatters into the FSDP shards rather than full
    all-reduces.
    """
    lr_fn = make_schedule(
        tcfg.schedule, tcfg.learning_rate, tcfg.warmup_steps, tcfg.total_steps
    )
    n_micro = microbatches or tcfg.microbatches

    def loss_of(p, mb):
        def cast(a, s=None):
            b = a.astype(jnp.bfloat16) if a.dtype == jnp.float32 else a
            # keep the bf16 copy on the master's FSDP shards so the convert
            # is local and the all-gather at use moves bf16, not fp32
            return b if s is None else jax.lax.with_sharding_constraint(b, s)

        if grad_shardings is None:
            pc = jax.tree_util.tree_map(cast, p)
        else:
            pc = jax.tree_util.tree_map(cast, p, grad_shardings)
        return loss_fn(pc, mb, cfg, sh=sh, remat=tcfg.remat, z_loss=tcfg.z_loss)

    def _constrain(grads):
        if grad_shardings is None:
            return grads
        return jax.tree_util.tree_map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s), grads, grad_shardings
        )

    def train_step(params, opt_state, batch, step):
        if n_micro > 1:
            mb = jax.tree_util.tree_map(
                lambda x: x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:]),
                batch,
            )

            def acc(carry, one):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_of, has_aux=True)(params, one)
                g = _constrain(g)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g
                )
                return (gsum, lsum + l), None

            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (gsum, lsum), _ = lax.scan(acc, (zero, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, gsum)
            loss = lsum / n_micro
        else:
            (loss, _), grads = jax.value_and_grad(loss_of, has_aux=True)(params, batch)
            grads = _constrain(grads)

        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        lr = lr_fn(step)
        params, opt_state = adamw_update(
            params, grads, opt_state, lr,
            b1=tcfg.b1, b2=tcfg.b2, eps=tcfg.eps, weight_decay=tcfg.weight_decay,
        )
        metrics = {"loss": loss, "gnorm": gnorm, "lr": lr}
        return params, opt_state, metrics

    return train_step


def make_dp_train_step(cfg, tcfg, mesh, dp_axis: str = "pod"):
    """Explicit data-parallel step over `dp_axis` with compressed gradients.

    Params/opt replicated across dp_axis; the batch splits along it; the
    cross-axis gradient reduction uses int8 codes + error feedback
    (parallel.compression).  opt_state gains an "ef" residual tree.
    Returns (train_step, init_fn) where init_fn wraps adamw_init.
    """
    from jax.experimental.shard_map import shard_map

    lr_fn = make_schedule(
        tcfg.schedule, tcfg.learning_rate, tcfg.warmup_steps, tcfg.total_steps
    )

    def loss_of(p, mb):
        return loss_fn(p, mb, cfg, remat=tcfg.remat, z_loss=tcfg.z_loss)

    def init_fn(params):
        st = adamw_init(params)
        st["ef"] = ef_init(params)
        return st

    def _step(params, opt_state, batch, step):
        (loss, _), grads = jax.value_and_grad(loss_of, has_aux=True)(params, batch)
        grads, new_ef = compressed_psum_mean(grads, opt_state["ef"], dp_axis)
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        lr = lr_fn(step)
        st = {k: opt_state[k] for k in ("m", "v", "count")}
        params, st = adamw_update(
            params, grads, st, lr,
            b1=tcfg.b1, b2=tcfg.b2, eps=tcfg.eps, weight_decay=tcfg.weight_decay,
        )
        st["ef"] = new_ef
        loss = lax.pmean(loss, dp_axis)
        return params, st, {"loss": loss, "gnorm": gnorm, "lr": lr}

    step_fn = shard_map(
        _step,
        mesh=mesh,
        in_specs=(P(), P(), P(dp_axis), P()),
        out_specs=(P(), P(), P()),
        check_rep=False,
    )
    return jax.jit(step_fn), init_fn
