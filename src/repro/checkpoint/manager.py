"""Fault-tolerant checkpointing: async save, atomic commit, keep-k, elastic
restore (re-shard onto any mesh by device_put with the target shardings).

Layout:  <dir>/step_<N>/arrays.npz + manifest.json  (tmp dir + atomic rename).
Leaves are addressed by their pytree key-path, so any same-structure tree
(params, opt state, data-iterator state) round-trips; restoring onto a
different mesh/topology only changes the NamedShardings passed to `restore`.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        arrays, _ = _flatten(tree)
        self.wait()

        def _write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step:08d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump({"step": step, "keys": sorted(arrays)}, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic commit
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None, shardings: Any = None):
        """Restore into the structure of `like`.

        `shardings` (optional pytree of jax.sharding.Sharding, same structure)
        re-shards every leaf onto the current mesh — elastic restart onto a
        different topology is just a different `shardings` argument.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}", "arrays.npz")
        data = np.load(path)
        arrays, _ = _flatten(like)
        flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
        flat_sh = (
            treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(flat_like)
        )
        leaves = []
        for (pth, leaf), shd in zip(flat_like, flat_sh):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in pth)
            arr = data[key]
            if hasattr(leaf, "dtype"):
                arr = arr.astype(leaf.dtype)
            leaves.append(jax.device_put(arr, shd) if shd is not None else jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves)
