"""Hypothesis property tests for the PKG invariants (paper §3.2, §5)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (
    hash_choices,
    local_imbalance_bound,
    pkg_partition,
    shuffle_partition,
    simulate_sources,
    source_assignment,
    zipf_stream,
)

keys_strategy = st.integers(min_value=0, max_value=2**31 - 1)


@settings(max_examples=25, deadline=None)
@given(
    st.lists(keys_strategy, min_size=10, max_size=400),
    st.integers(min_value=2, max_value=32),
    st.integers(min_value=2, max_value=4),
)
def test_pkg_routes_only_to_candidates(keys, n_workers, d):
    ks = jnp.asarray(np.asarray(keys, np.int32))
    a = np.asarray(pkg_partition(ks, n_workers, d=d))
    cand = np.asarray(hash_choices(ks, n_workers, d=d))
    assert (a[:, None] == cand).any(axis=1).all()


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=1, max_value=5000),
    st.integers(min_value=2, max_value=64),
)
def test_shuffle_perfect_balance(m, n_workers):
    a = np.asarray(shuffle_partition(jnp.zeros(m, jnp.int32), n_workers))
    loads = np.bincount(a, minlength=n_workers)
    assert loads.max() - loads.min() <= 1


@settings(max_examples=10, deadline=None)
@given(
    st.integers(min_value=0, max_value=10_000),
    st.integers(min_value=2, max_value=16),
    st.sampled_from([0.5, 1.0, 1.5]),
    st.integers(min_value=1, max_value=8),
)
def test_local_imbalance_upper_bounds_global(seed, n_workers, z, n_sources):
    """Theorem §3.2: I(t) <= sum_j local imbalances, for the realized loads."""
    keys = zipf_stream(4000, 500, z, seed=seed)
    assign = simulate_sources(keys, n_workers, n_sources=n_sources, mode="local")
    src = source_assignment(len(keys), n_sources)
    gi, li = local_imbalance_bound(keys, assign, src, n_workers, n_sources)
    assert gi <= li + 1e-9


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=1000))
def test_load_conservation(seed):
    keys = zipf_stream(2000, 100, 1.2, seed=seed)
    a = np.asarray(pkg_partition(jnp.asarray(keys), 8))
    assert np.bincount(a, minlength=8).sum() == len(keys)


@settings(max_examples=10, deadline=None)
@given(
    st.integers(min_value=0, max_value=100),
    st.integers(min_value=1, max_value=6),
)
def test_hash_choices_uniform_and_independent_of_order(seed, d):
    keys = np.arange(1000, dtype=np.int32)
    c1 = np.asarray(hash_choices(jnp.asarray(keys), 16, d=d, seed=seed))
    perm = np.random.default_rng(0).permutation(1000)
    c2 = np.asarray(hash_choices(jnp.asarray(keys[perm]), 16, d=d, seed=seed))
    assert (c1[perm] == c2).all()
    # rough uniformity: each worker gets 1000*d/16 ± 5 sigma
    counts = np.bincount(c1.reshape(-1), minlength=16)
    expect = 1000 * d / 16
    assert (np.abs(counts - expect) < 5 * np.sqrt(expect) + 10).all()
