"""Hypothesis property tests for the PKG invariants (paper §3.2, §5).

Requires the `test` extra (pip install -e ".[test]"); the whole module is
skipped when hypothesis is absent so the tier-1 suite stays green without
optional deps.
"""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (  # noqa: E402
    d_choices_partition,
    hash_choices,
    local_imbalance_bound,
    pkg_partition,
    shuffle_partition,
    simulate_sources,
    source_assignment,
    w_choices_partition,
    zipf_stream,
)
from repro.core.metrics import final_imbalance_fraction, loads_from_assignment  # noqa: E402

keys_strategy = st.integers(min_value=0, max_value=2**31 - 1)


@settings(max_examples=25, deadline=None)
@given(
    st.lists(keys_strategy, min_size=10, max_size=400),
    st.integers(min_value=2, max_value=32),
    st.integers(min_value=2, max_value=4),
)
def test_pkg_routes_only_to_candidates(keys, n_workers, d):
    ks = jnp.asarray(np.asarray(keys, np.int32))
    a = np.asarray(pkg_partition(ks, n_workers, d=d))
    cand = np.asarray(hash_choices(ks, n_workers, d=d))
    assert (a[:, None] == cand).any(axis=1).all()


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=1, max_value=5000),
    st.integers(min_value=2, max_value=64),
)
def test_shuffle_perfect_balance(m, n_workers):
    a = np.asarray(shuffle_partition(jnp.zeros(m, jnp.int32), n_workers))
    loads = np.bincount(a, minlength=n_workers)
    assert loads.max() - loads.min() <= 1


@settings(max_examples=10, deadline=None)
@given(
    st.integers(min_value=0, max_value=10_000),
    st.integers(min_value=2, max_value=16),
    st.sampled_from([0.5, 1.0, 1.5]),
    st.integers(min_value=1, max_value=8),
)
def test_local_imbalance_upper_bounds_global(seed, n_workers, z, n_sources):
    """Theorem §3.2: I(t) <= sum_j local imbalances, for the realized loads."""
    keys = zipf_stream(4000, 500, z, seed=seed)
    assign = simulate_sources(keys, n_workers, n_sources=n_sources, mode="local")
    src = source_assignment(len(keys), n_sources)
    gi, li = local_imbalance_bound(keys, assign, src, n_workers, n_sources)
    assert gi <= li + 1e-9


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=1000))
def test_load_conservation(seed):
    keys = zipf_stream(2000, 100, 1.2, seed=seed)
    a = np.asarray(pkg_partition(jnp.asarray(keys), 8))
    assert np.bincount(a, minlength=8).sum() == len(keys)


@settings(max_examples=10, deadline=None)
@given(
    st.integers(min_value=0, max_value=100),
    st.integers(min_value=1, max_value=6),
)
def test_hash_choices_uniform_and_independent_of_order(seed, d):
    keys = np.arange(1000, dtype=np.int32)
    c1 = np.asarray(hash_choices(jnp.asarray(keys), 16, d=d, seed=seed))
    perm = np.random.default_rng(0).permutation(1000)
    c2 = np.asarray(hash_choices(jnp.asarray(keys[perm]), 16, d=d, seed=seed))
    assert (c1[perm] == c2).all()
    # rough uniformity: each worker gets 1000*d/16 ± 5 sigma
    counts = np.bincount(c1.reshape(-1), minlength=16)
    expect = 1000 * d / 16
    assert (np.abs(counts - expect) < 5 * np.sqrt(expect) + 10).all()


@settings(max_examples=10, deadline=None)
@given(
    st.integers(min_value=0, max_value=1000),
    st.sampled_from([1.5, 1.8, 2.0]),
)
def test_adaptive_choices_never_worse_than_pkg_at_scale(seed, z):
    """arXiv 1510.05714: past p1 > d/W, D- and W-Choices dominate PKG."""
    W = 100
    keys = zipf_stream(20_000, 2_000, z, seed=seed)
    pkg = final_imbalance_fraction(np.asarray(pkg_partition(jnp.asarray(keys), W)), W)
    dch = final_imbalance_fraction(np.asarray(d_choices_partition(keys, W)), W)
    wch = final_imbalance_fraction(np.asarray(w_choices_partition(keys, W)), W)
    assert dch <= pkg + 1e-9, (dch, pkg)
    assert wch <= pkg + 1e-9, (wch, pkg)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=1000))
def test_adaptive_choices_conserve_and_stay_in_range(seed):
    keys = zipf_stream(5_000, 500, 1.6, seed=seed)
    for part in (d_choices_partition, w_choices_partition):
        a = np.asarray(part(keys, 50))
        assert a.min() >= 0 and a.max() < 50
        assert loads_from_assignment(a, 50).sum() == len(keys)
