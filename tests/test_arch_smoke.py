"""Per-assigned-architecture smoke tests (deliverable f): a REDUCED config of
the same family runs one forward + one train step on CPU, asserting shapes
and finiteness; decode agrees with the full-sequence forward (prefill/decode
consistency — a strong cache-correctness check)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, TrainConfig, get_config, make_tiny
from repro.models import decode_step, forward, init_cache, init_params
from repro.train import make_train_step

B, S = 2, 64


def _batch(cfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.frontend == "audio_stub":
        return {
            "embeds": jax.random.normal(k1, (B, S, cfg.d_model)),
            "labels": jax.random.randint(k2, (B, S, cfg.n_io_heads), 0, cfg.vocab_size),
        }
    return {
        "tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(k3, (B, S), 0, cfg.vocab_size),
    }


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_train_step(arch):
    cfg = make_tiny(get_config(arch))
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)

    logits, aux = forward(params, batch, cfg)
    expect = (
        (B, S, cfg.n_io_heads, cfg.vocab_padded)
        if cfg.n_io_heads > 1
        else (B, S, cfg.vocab_padded)
    )
    assert logits.shape == expect
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    from repro.optim import adamw_init

    tcfg = TrainConfig(total_steps=10, warmup_steps=2)
    step = jax.jit(make_train_step(cfg, tcfg))
    p2, o2, metrics = step(params, adamw_init(params), batch, jnp.int32(0))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["gnorm"]))
    # params actually changed
    changed = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(p2))
    )
    assert changed


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_consistency(arch):
    """Greedy next-token from decode-loop == from full forward."""
    cfg = make_tiny(get_config(arch))
    if cfg.frontend == "audio_stub":
        pytest.skip("stub frontend drives embeddings, covered in forward test")
    if cfg.n_experts:
        # capacity dropping legitimately differs between prefill (many tokens
        # compete) and decode (few) — remove drops for the consistency check
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (B, 16), 0, cfg.vocab_size)

    logits_full, _ = forward(params, {"tokens": toks}, cfg)
    cache = init_cache(cfg, B, max_len=64)
    logits_dec = None
    for t in range(16):
        logits_dec, cache = decode_step(
            params, cache, {"tokens": toks[:, t : t + 1]}, jnp.int32(t), cfg
        )
    a = np.asarray(logits_full[:, -1].astype(jnp.float32))
    b = np.asarray(logits_dec[:, 0].astype(jnp.float32))
    # bf16 compute: compare argmax (greedy token) and coarse values
    np.testing.assert_array_equal(a.argmax(-1), b.argmax(-1))
    np.testing.assert_allclose(a, b, atol=0.15, rtol=0.05)


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "olmoe-1b-7b"])
def test_pkg_router_variant_trains(arch):
    """PKG-PoTC routing is a drop-in: train step runs and grads flow."""
    from repro.optim import adamw_init

    cfg = dataclasses.replace(make_tiny(get_config(arch)), router="pkg_potc")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)
    tcfg = TrainConfig(total_steps=10, warmup_steps=2)
    step = jax.jit(make_train_step(cfg, tcfg))
    _, _, metrics = step(params, adamw_init(params), batch, jnp.int32(0))
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["gnorm"]) > 0


@pytest.mark.parametrize("router", ["d_choices", "w_choices"])
def test_adaptive_router_variant_trains(router):
    """D-/W-Choices routing closes the training loop: the jitted train step
    runs (head-table scan + shared-core dispatch inside the loss), the loss
    is finite, and gradients flow — including to the router weights, which
    only see gradients through the selected gate values."""
    from repro.optim import adamw_init

    cfg = dataclasses.replace(make_tiny(get_config("olmoe-1b-7b")), router=router)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)
    tcfg = TrainConfig(total_steps=10, warmup_steps=2)
    step = jax.jit(make_train_step(cfg, tcfg))
    p2, _, metrics = step(params, adamw_init(params), batch, jnp.int32(0))
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["gnorm"]) > 0
    before = jax.tree_util.tree_leaves_with_path(params)
    after = jax.tree_util.tree_leaves(p2)
    moved = any(
        "router" in jax.tree_util.keystr(path)
        and not np.allclose(np.asarray(a), np.asarray(b))
        for (path, a), b in zip(before, after)
    )
    assert moved, "router weights must receive gradients through the gates"
