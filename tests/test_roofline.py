"""Roofline machinery: HLO collective parser, terms, model flops."""
import numpy as np

from repro.configs import get_config
from repro.roofline.analysis import (
    HW,
    collective_bytes,
    model_flops,
    roofline_report,
)

FAKE_HLO = """
HloModule jit_step
  %all-gather.1 = f32[16,128]{1,0} all-gather(%x), dimensions={0}
  %all-reduce.2 = (bf16[256]{0}, f32[]) all-reduce(%y, %z), to_apply=%add
  %reduce-scatter.3 = s8[1024]{0} reduce-scatter(%w), dimensions={0}
  %all-to-all.4 = u32[64,2]{1,0} all-to-all(%v), dimensions={0}
  %collective-permute-start.5 = bf16[8,8]{1,0} collective-permute-start(%u)
  %dot.6 = f32[128,128]{1,0} dot(%a, %b)
"""


def test_collective_parser_counts_and_bytes():
    out = collective_bytes(FAKE_HLO, bf16_wire=False)
    assert out["all-gather"] == 16 * 128 * 4
    assert out["all-reduce"] == 256 * 2 + 4
    assert out["reduce-scatter"] == 1024
    assert out["all-to-all"] == 64 * 2 * 4
    assert out["collective-permute"] == 8 * 8 * 2
    assert out["counts"]["all-gather"] == 1
    assert out["total"] == sum(
        out[k] for k in ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute")
    )


def test_bf16_wire_correction_halves_f32_only():
    raw = collective_bytes(FAKE_HLO, bf16_wire=False)
    cor = collective_bytes(FAKE_HLO, bf16_wire=True)
    assert cor["all-gather"] == raw["all-gather"] // 2  # f32 halved
    assert cor["collective-permute"] == raw["collective-permute"]  # bf16 kept
    assert cor["reduce-scatter"] == raw["reduce-scatter"]  # int8 kept


def test_roofline_dominant_and_fraction():
    hw = HW(peak_flops=1e12, hbm_bw=1e9, ici_bw=1e9)
    r = roofline_report(1e12, 0.5e9, 2e9, hw=hw)  # 1s comp, 0.5s mem, 2s coll
    assert r["dominant"] == "collective"
    assert abs(r["step_lower_bound_s"] - 2.0) < 1e-9
    assert abs(r["roofline_fraction"] - 0.5) < 1e-9


def test_model_flops_moe_counts_active_only():
    dense = get_config("qwen2.5-3b")
    moe = get_config("mixtral-8x7b")
    assert model_flops(dense, "train", 1000) == 6.0 * dense.param_count() * 1000
    assert moe.active_param_count() < moe.param_count() / 2
    assert model_flops(moe, "prefill", 10) == 2.0 * moe.active_param_count() * 10


def test_param_counts_order_of_magnitude():
    """Config param counts land near the models' nameplate sizes."""
    expect = {
        "qwen2.5-3b": (2.5e9, 4.5e9),
        "deepseek-67b": (60e9, 75e9),
        "mixtral-8x7b": (40e9, 52e9),
        "mamba2-1.3b": (1.0e9, 1.6e9),
        "gemma3-4b": (3.0e9, 5.5e9),
        "chameleon-34b": (30e9, 40e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n)
