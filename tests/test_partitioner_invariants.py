"""Differential partitioner invariants (deterministic; always in tier-1).

Covers the cross-implementation contracts that must hold exactly:
  (a) pkg_partition_batched(block=1) == pkg_partition, message for message
  (b) every PKG assignment lies in the key's hash_choices candidate set
  (c) shuffle imbalance <= 1
  (d) D-/W-Choices imbalance <= PKG on Zipf z >= 1.5 at n_workers = 100
  (e) the fully-online adaptive variants vs their offline pre-pass twins:
      frozen-carry online == offline bit-exactly (two very different code
      paths computing the same decisions), tail-only streams == PKG, and the
      decayed online tracker wins under head-key drift
plus the adaptive partitioners' tail-key contract: with no head keys they
reproduce PKG bit-exactly (same candidates, same tie-breaking).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SCALE_SCENARIOS,
    SpaceSavingTracker,
    adaptive_d,
    d_choices_partition,
    drift_stream,
    hash_choices,
    head_threshold,
    online_d_choices_partition,
    online_ss_from_tracker,
    online_w_choices_partition,
    pkg_partition,
    pkg_partition_batched,
    shuffle_partition,
    w_choices_kernel_partition,
    w_choices_partition,
    zipf_stream,
)
from repro.core.metrics import avg_imbalance_fraction, final_imbalance_fraction


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("z", [0.8, 1.4])
def test_batched_block1_equals_sequential(seed, z):
    """(a) A block of one key is exactly the sequential greedy scan."""
    keys = jnp.asarray(zipf_stream(3_000, 400, z, seed=seed))
    a_seq = np.asarray(pkg_partition(keys, 12))
    a_b1 = np.asarray(pkg_partition_batched(keys, 12, block=1))
    np.testing.assert_array_equal(a_seq, a_b1)


@pytest.mark.parametrize("d", [2, 3, 4])
@pytest.mark.parametrize("n_workers", [5, 16, 100])
def test_pkg_assignment_within_candidates(d, n_workers):
    """(b) PKG only ever routes to one of the key's d hash candidates."""
    keys = jnp.asarray(zipf_stream(4_000, 600, 1.2, seed=d))
    a = np.asarray(pkg_partition(keys, n_workers, d=d))
    cand = np.asarray(hash_choices(keys, n_workers, d=d))
    assert (a[:, None] == cand).any(axis=1).all()


@pytest.mark.parametrize("m,n_workers", [(1, 2), (97, 10), (10_000, 64)])
def test_shuffle_imbalance_at_most_one(m, n_workers):
    """(c) Round-robin is perfectly balanced up to integrality."""
    a = np.asarray(shuffle_partition(jnp.zeros(m, jnp.int32), n_workers))
    loads = np.bincount(a, minlength=n_workers)
    assert loads.max() - loads.min() <= 1


@pytest.mark.parametrize("name", ["W100_z1.6", "W100_z2.0", "W50_z1.8"])
def test_adaptive_beats_pkg_on_scale_scenarios(name):
    """(d) In the large-deployment regime the adaptive variants dominate."""
    sc = SCALE_SCENARIOS[name]
    keys = sc.generate(seed=11, scale=0.25)
    W = sc.n_workers
    assert sc.head_fraction() > head_threshold(W), "scenario must be PKG-hard"
    pkg = final_imbalance_fraction(np.asarray(pkg_partition(jnp.asarray(keys), W)), W)
    dch = final_imbalance_fraction(np.asarray(d_choices_partition(keys, W)), W)
    wch = final_imbalance_fraction(np.asarray(w_choices_partition(keys, W)), W)
    assert dch < pkg, (name, dch, pkg)
    assert wch < pkg, (name, wch, pkg)
    assert wch < 1e-3, (name, wch)  # head-anywhere restores near-perfection


def test_adaptive_equals_pkg_without_head_keys():
    """Tail keys keep PKG's exact routing: below-threshold streams match."""
    keys = zipf_stream(20_000, 5_000, 0.5, seed=3)  # p1 << d/W
    a_pkg = np.asarray(pkg_partition(jnp.asarray(keys), 10))
    np.testing.assert_array_equal(a_pkg, np.asarray(d_choices_partition(keys, 10)))
    np.testing.assert_array_equal(a_pkg, np.asarray(w_choices_partition(keys, 10)))


@pytest.mark.parametrize("name", ["W100_z1.6", "W100_z2.0"])
def test_w_choices_kernel_near_perfect_on_scale_scenarios(name):
    """(d) for the device path: the in-kernel W router (default block=128,
    global-argmin water-fill) keeps the near-perfect balance of the
    sequential W-Choices where PKG explodes — the gap ROADMAP open item 1
    existed for."""
    sc = SCALE_SCENARIOS[name]
    keys = sc.generate(seed=11, scale=0.25)
    W = sc.n_workers
    pkg = final_imbalance_fraction(np.asarray(pkg_partition(jnp.asarray(keys), W)), W)
    wk = final_imbalance_fraction(
        np.asarray(w_choices_kernel_partition(keys, W)), W
    )
    assert wk < pkg / 10, (name, wk, pkg)
    assert wk < 5e-3, (name, wk)


def test_d_choices_candidates_extend_pkg_candidates():
    """d(k) >= 2 candidates always include PKG's two (seed-prefix property)."""
    keys = jnp.asarray(zipf_stream(1_000, 100, 1.0, seed=0))
    c2 = np.asarray(hash_choices(keys, 32, d=2))
    c8 = np.asarray(hash_choices(keys, 32, d=8))
    np.testing.assert_array_equal(c2, c8[:, :2])


@pytest.mark.parametrize("z", [1.4, 1.8])
def test_online_frozen_equals_offline_differentially(z):
    """(e) The online scan with a warm frozen carry must reproduce the offline
    pre-pass variants bit-exactly: the offline path computes head sets and
    d(k) in numpy (searchsorted lookup, int64), the online path recomputes
    them per element inside the lax.scan carry (int32 table probes) — any
    divergence in threshold/tie-breaking/integer-ceil logic shows up here."""
    W, cap = 100, 256
    keys = zipf_stream(20_000, 5_000, z, seed=int(z * 10))
    tracker = SpaceSavingTracker(cap)
    tracker.update(np.asarray(keys, np.int32))
    state = online_ss_from_tracker(tracker, cap)
    a_off = np.asarray(d_choices_partition(keys, W, capacity=cap))
    a_on = np.asarray(
        online_d_choices_partition(
            keys, W, capacity=cap, init_state=state, update_tracker=False
        )
    )
    np.testing.assert_array_equal(a_off, a_on)
    w_off = np.asarray(w_choices_partition(keys, W, capacity=cap))
    w_on = np.asarray(
        online_w_choices_partition(
            keys, W, capacity=cap, init_state=state, update_tracker=False
        )
    )
    np.testing.assert_array_equal(w_off, w_on)


def test_online_frozen_equals_offline_adversarial_small_stream():
    """(e) Boundary regression: a key seen 7 times in 300 messages clears
    theta = 0.02 by fraction (7/300) but not the min_count floor — offline
    and frozen-carry online must make the SAME call (both use the canonical
    head_test with min_count), or the differential contract breaks exactly
    where estimates are noisiest."""
    W, cap = 100, 64
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 200, 300).astype(np.int32)
    keys[rng.choice(300, 7, replace=False)] = 777  # 7/300 >= theta, < min_count
    tracker = SpaceSavingTracker(cap)
    tracker.update(keys)
    state = online_ss_from_tracker(tracker, cap)
    a_off = np.asarray(d_choices_partition(keys, W, capacity=cap))
    a_on = np.asarray(
        online_d_choices_partition(
            keys, W, capacity=cap, init_state=state, update_tracker=False
        )
    )
    np.testing.assert_array_equal(a_off, a_on)
    w_off = np.asarray(w_choices_partition(keys, W, capacity=cap))
    w_on = np.asarray(
        online_w_choices_partition(
            keys, W, capacity=cap, init_state=state, update_tracker=False
        )
    )
    np.testing.assert_array_equal(w_off, w_on)


def test_online_equals_pkg_without_head_keys():
    """(e) Cold-start online on a below-threshold stream is PKG bit-exactly —
    live tracker updates included, no key ever clears theta."""
    keys = zipf_stream(20_000, 5_000, 0.5, seed=3)  # p1 << d/W
    a_pkg = np.asarray(pkg_partition(jnp.asarray(keys), 10))
    np.testing.assert_array_equal(
        a_pkg, np.asarray(online_d_choices_partition(keys, 10))
    )
    np.testing.assert_array_equal(
        a_pkg, np.asarray(online_w_choices_partition(keys, 10))
    )


def test_online_matches_offline_on_stationary_stream():
    """(e) Live (cold-start, updating) online lands on the offline variant's
    balance once the head set is stable."""
    W = 100
    keys = zipf_stream(30_000, 5_000, 1.8, seed=11)
    d_off = final_imbalance_fraction(
        np.asarray(d_choices_partition(keys, W, capacity=256)), W
    )
    d_on = final_imbalance_fraction(
        np.asarray(online_d_choices_partition(keys, W, capacity=256)), W
    )
    assert d_on <= 1.2 * d_off + 1e-4, (d_on, d_off)
    w_off = final_imbalance_fraction(
        np.asarray(w_choices_partition(keys, W, capacity=256)), W
    )
    w_on = final_imbalance_fraction(
        np.asarray(online_w_choices_partition(keys, W, capacity=256)), W
    )
    assert w_on <= 2.0 * w_off + 1e-4, (w_on, w_off)


def test_online_decayed_beats_offline_under_drift():
    """(e) The tentpole claim, in-suite at reduced size: when the head set
    churns, the whole-stream pre-pass dilutes below theta while the decayed
    online tracker follows the rotation."""
    W, m = 100, 40_000
    keys = drift_stream(m, 5_000, 1.8, half_life=m // 8, seed=5)
    decay = m // 16
    w_off = avg_imbalance_fraction(
        np.asarray(w_choices_partition(keys, W, capacity=256)), W
    )
    w_on = avg_imbalance_fraction(
        np.asarray(
            online_w_choices_partition(keys, W, capacity=256, decay_period=decay)
        ),
        W,
    )
    assert w_on < w_off, (w_on, w_off)


def test_space_saving_tracker_finds_true_head():
    keys = zipf_stream(50_000, 5_000, 1.8, seed=7)
    tracker = SpaceSavingTracker(capacity=512)
    tracker.update(keys)
    counts = np.bincount(keys)
    true_head = set(np.flatnonzero(counts / len(keys) >= 0.02).tolist())
    ids, p_hat = tracker.head_keys(0.02)
    assert true_head <= set(ids.tolist())  # no false negatives
    # overestimation is bounded by total/capacity
    for k, p in zip(ids, p_hat):
        assert p <= counts[k] / len(keys) + 1.0 / 512 + 1e-12


def test_adaptive_d_rule():
    p = np.array([0.001, 0.02, 0.3, 0.9])
    d = adaptive_d(p, n_workers=100, d_base=2, d_max=16)
    assert d.tolist() == [2, 4, 16, 16]
    assert (adaptive_d(p, n_workers=4, d_base=2, d_max=4) <= 4).all()
