"""Differential partitioner invariants (deterministic; always in tier-1).

Covers the cross-implementation contracts that must hold exactly:
  (a) pkg_partition_batched(block=1) == pkg_partition, message for message
  (b) every PKG assignment lies in the key's hash_choices candidate set
  (c) shuffle imbalance <= 1
  (d) D-/W-Choices imbalance <= PKG on Zipf z >= 1.5 at n_workers = 100
plus the adaptive partitioners' tail-key contract: with no head keys they
reproduce PKG bit-exactly (same candidates, same tie-breaking).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SCALE_SCENARIOS,
    SpaceSavingTracker,
    adaptive_d,
    d_choices_partition,
    hash_choices,
    head_threshold,
    pkg_partition,
    pkg_partition_batched,
    shuffle_partition,
    w_choices_partition,
    zipf_stream,
)
from repro.core.metrics import final_imbalance_fraction


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("z", [0.8, 1.4])
def test_batched_block1_equals_sequential(seed, z):
    """(a) A block of one key is exactly the sequential greedy scan."""
    keys = jnp.asarray(zipf_stream(3_000, 400, z, seed=seed))
    a_seq = np.asarray(pkg_partition(keys, 12))
    a_b1 = np.asarray(pkg_partition_batched(keys, 12, block=1))
    np.testing.assert_array_equal(a_seq, a_b1)


@pytest.mark.parametrize("d", [2, 3, 4])
@pytest.mark.parametrize("n_workers", [5, 16, 100])
def test_pkg_assignment_within_candidates(d, n_workers):
    """(b) PKG only ever routes to one of the key's d hash candidates."""
    keys = jnp.asarray(zipf_stream(4_000, 600, 1.2, seed=d))
    a = np.asarray(pkg_partition(keys, n_workers, d=d))
    cand = np.asarray(hash_choices(keys, n_workers, d=d))
    assert (a[:, None] == cand).any(axis=1).all()


@pytest.mark.parametrize("m,n_workers", [(1, 2), (97, 10), (10_000, 64)])
def test_shuffle_imbalance_at_most_one(m, n_workers):
    """(c) Round-robin is perfectly balanced up to integrality."""
    a = np.asarray(shuffle_partition(jnp.zeros(m, jnp.int32), n_workers))
    loads = np.bincount(a, minlength=n_workers)
    assert loads.max() - loads.min() <= 1


@pytest.mark.parametrize("name", ["W100_z1.6", "W100_z2.0", "W50_z1.8"])
def test_adaptive_beats_pkg_on_scale_scenarios(name):
    """(d) In the large-deployment regime the adaptive variants dominate."""
    sc = SCALE_SCENARIOS[name]
    keys = sc.generate(seed=11, scale=0.25)
    W = sc.n_workers
    assert sc.head_fraction() > head_threshold(W), "scenario must be PKG-hard"
    pkg = final_imbalance_fraction(np.asarray(pkg_partition(jnp.asarray(keys), W)), W)
    dch = final_imbalance_fraction(np.asarray(d_choices_partition(keys, W)), W)
    wch = final_imbalance_fraction(np.asarray(w_choices_partition(keys, W)), W)
    assert dch < pkg, (name, dch, pkg)
    assert wch < pkg, (name, wch, pkg)
    assert wch < 1e-3, (name, wch)  # head-anywhere restores near-perfection


def test_adaptive_equals_pkg_without_head_keys():
    """Tail keys keep PKG's exact routing: below-threshold streams match."""
    keys = zipf_stream(20_000, 5_000, 0.5, seed=3)  # p1 << d/W
    a_pkg = np.asarray(pkg_partition(jnp.asarray(keys), 10))
    np.testing.assert_array_equal(a_pkg, np.asarray(d_choices_partition(keys, 10)))
    np.testing.assert_array_equal(a_pkg, np.asarray(w_choices_partition(keys, 10)))


def test_d_choices_candidates_extend_pkg_candidates():
    """d(k) >= 2 candidates always include PKG's two (seed-prefix property)."""
    keys = jnp.asarray(zipf_stream(1_000, 100, 1.0, seed=0))
    c2 = np.asarray(hash_choices(keys, 32, d=2))
    c8 = np.asarray(hash_choices(keys, 32, d=8))
    np.testing.assert_array_equal(c2, c8[:, :2])


def test_space_saving_tracker_finds_true_head():
    keys = zipf_stream(50_000, 5_000, 1.8, seed=7)
    tracker = SpaceSavingTracker(capacity=512)
    tracker.update(keys)
    counts = np.bincount(keys)
    true_head = set(np.flatnonzero(counts / len(keys) >= 0.02).tolist())
    ids, p_hat = tracker.head_keys(0.02)
    assert true_head <= set(ids.tolist())  # no false negatives
    # overestimation is bounded by total/capacity
    for k, p in zip(ids, p_hat):
        assert p <= counts[k] / len(keys) + 1.0 / 512 + 1e-12


def test_adaptive_d_rule():
    p = np.array([0.001, 0.02, 0.3, 0.9])
    d = adaptive_d(p, n_workers=100, d_base=2, d_max=16)
    assert d.tolist() == [2, 4, 16, 16]
    assert (adaptive_d(p, n_workers=4, d_base=2, d_max=4) <= 4).all()
