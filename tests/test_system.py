"""End-to-end behaviour: the full framework trains a small model on the
PKG-balanced pipeline and the loss drops; the paper's headline claim
(PKG >> KG balance, throughput ~ SG) holds on the integrated path."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig, get_config, make_tiny
from repro.core import (
    QueueModel,
    hash_partition,
    pkg_partition,
    shuffle_partition,
    zipf_stream,
)
from repro.data import PKGDataPipeline, SyntheticCorpus
from repro.models import init_params
from repro.optim import adamw_init
from repro.train import make_train_step


def test_end_to_end_training_loss_decreases():
    cfg = make_tiny(get_config("qwen2.5-3b"))
    tcfg = TrainConfig(total_steps=30, warmup_steps=3, learning_rate=2e-3)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    pipe = PKGDataPipeline(
        batch_size=4, seq_len=64, vocab_size=cfg.vocab_size,
        corpus=SyntheticCorpus(cfg.vocab_size, n_keys=128, seed=1), seed=1,
    )
    step = jax.jit(make_train_step(cfg, tcfg))
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        params, opt, metrics = step(params, opt, batch, jnp.int32(i))
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_paper_headline_throughput_claim():
    """Queue model on a skewed stream: PKG ~ SG throughput >> KG (Fig 10)."""
    # keep p1 well below d/W (paper §5) so PKG can reach SG-level balance
    keys = zipf_stream(200_000, 5_000, 1.1, seed=3)
    W, D = 8, 1e-4
    ks = jnp.asarray(keys)
    t_kg = QueueModel(np.asarray(hash_partition(ks, W)), W, D).saturation_throughput
    t_pkg = QueueModel(np.asarray(pkg_partition(ks, W)), W, D).saturation_throughput
    t_sg = QueueModel(np.asarray(shuffle_partition(ks, W)), W, D).saturation_throughput
    assert t_pkg > 1.2 * t_kg, (t_pkg, t_kg)
    assert t_pkg > 0.95 * t_sg, (t_pkg, t_sg)


def test_microbatched_step_matches_single_batch():
    """Gradient accumulation is numerically consistent with one big batch."""
    cfg = make_tiny(get_config("h2o-danube-1.8b"))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = {
        "tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(9), (4, 32), 0, cfg.vocab_size),
    }
    tcfg1 = TrainConfig(total_steps=10, warmup_steps=1, microbatches=1)
    tcfg2 = TrainConfig(total_steps=10, warmup_steps=1, microbatches=2)
    p1, _, m1 = jax.jit(make_train_step(cfg, tcfg1))(params, adamw_init(params), batch, jnp.int32(0))
    p2, _, m2 = jax.jit(make_train_step(cfg, tcfg2))(params, adamw_init(params), batch, jnp.int32(0))
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 0.03
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-2)
