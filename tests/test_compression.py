"""int8 gradient compression: quantization error bounds and error feedback."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.compression import dequantize_int8, ef_init, quantize_int8


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_quantize_preserves_zero_and_sign():
    x = jnp.asarray([0.0, 1.0, -1.0, 0.5, -0.5])
    q, s = quantize_int8(x)
    d = np.asarray(dequantize_int8(q, s))
    assert d[0] == 0.0 and d[1] > 0 and d[2] < 0


def test_error_feedback_corrects_bias_over_steps():
    """With EF, the *accumulated* compressed signal tracks the true sum."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(256,)) * 0.01)
    e = jnp.zeros_like(g_true)
    sent_total = np.zeros(256)
    for _ in range(50):
        target = g_true + e
        q, s = quantize_int8(target)
        sent = dequantize_int8(q, s)
        e = target - sent
        sent_total += np.asarray(sent)
    true_total = np.asarray(g_true) * 50
    # relative error of the accumulated signal shrinks (EF property)
    rel = np.abs(sent_total - true_total).max() / np.abs(true_total).max()
    assert rel < 0.05, rel


def test_ef_init_matches_structure():
    tree = {"a": jnp.zeros((2, 3)), "b": {"c": jnp.zeros(5)}}
    ef = ef_init(tree)
    assert jax.tree_util.tree_structure(ef) == jax.tree_util.tree_structure(tree)
    assert all(float(jnp.sum(l)) == 0 for l in jax.tree_util.tree_leaves(ef))
