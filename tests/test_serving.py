"""Serving layer: replica scheduler balance/accounting + engine generation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, make_tiny
from repro.core import zipf_stream
from repro.models import init_params
from repro.serving import (
    KGScheduler,
    PoTCScheduler,
    RoundRobinScheduler,
    ServeEngine,
    WChoicesScheduler,
)


def _drive(sched, keys, costs):
    for k, c in zip(keys, costs):
        sched.route(int(k), float(c))
    loads = sched.loads
    return (loads.max() - loads.mean()) / max(loads.sum(), 1)


def test_potc_balances_hot_sessions():
    # p1 must stay below d/W for balance to be attainable (paper §5):
    # K=2000, z=1.1 gives p1 ~= 0.12 < 2/8
    keys = zipf_stream(20_000, 2_000, 1.1, seed=1)
    costs = np.ones(len(keys))
    f_potc = _drive(PoTCScheduler(8), keys, costs)
    f_kg = _drive(KGScheduler(8), keys, costs)
    assert f_potc < f_kg / 5, (f_potc, f_kg)
    assert f_potc < 0.02, f_potc


def test_potc_bounded_replica_fanout():
    """A session key only ever lands on <= 2 replicas (prefix-cache affinity)."""
    sched = PoTCScheduler(16)
    seen = {}
    keys = zipf_stream(5_000, 50, 1.0, seed=2)
    for k in keys:
        r = sched.route(int(k))
        seen.setdefault(int(k), set()).add(r)
    assert max(len(v) for v in seen.values()) <= 2


@pytest.mark.parametrize(
    "make",
    [PoTCScheduler, KGScheduler, RoundRobinScheduler, WChoicesScheduler],
    ids=["potc", "kg", "rr", "w_choices"],
)
def test_release_completion_accounting(make):
    """route adds exactly `cost`; complete releases it; never negative."""
    s = make(4)
    routed = []
    for i, cost in enumerate([10.0, 3.5, 1.0, 7.25] * 5):
        r = s.route(i % 7, cost=cost)
        assert 0 <= r < 4
        routed.append((r, cost))
        assert s.loads.sum() == pytest.approx(sum(c for _, c in routed))
    for r, cost in routed:
        s.complete(r, cost=cost)
    assert s.loads.sum() == pytest.approx(0.0)
    assert (s.loads >= 0).all()
    s.complete(0, cost=99.0)  # over-release clamps at zero
    assert (s.loads >= 0).all()


def test_complete_decrements():
    s = PoTCScheduler(4)
    r = s.route(123, cost=10.0)
    s.complete(r, cost=10.0)
    assert s.loads.sum() == 0


def test_round_robin_uniform():
    s = RoundRobinScheduler(5)
    for i in range(100):
        s.route(i)
    assert s.loads.max() - s.loads.min() <= 1


def test_w_choices_balances_past_potc_limit():
    """One session at p1 > d/W: PoTC saturates two replicas, W-Choices spreads."""
    n = 16
    rng = np.random.default_rng(0)
    # 60% of requests from one hot session id, rest uniform cold sessions
    keys = np.where(rng.random(20_000) < 0.6, 7, rng.integers(100, 5000, 20_000))
    potc, wch = PoTCScheduler(n), WChoicesScheduler(n)
    for k in keys:
        potc.route(int(k))
        wch.route(int(k))
    f_potc = (potc.loads.max() - potc.loads.mean()) / potc.loads.sum()
    f_wch = (wch.loads.max() - wch.loads.mean()) / wch.loads.sum()
    assert f_wch < f_potc / 5, (f_wch, f_potc)
    assert f_wch < 0.01, f_wch


def test_w_choices_cold_keys_keep_bounded_fanout():
    """Cold session ids still land on <= d replicas; hot ids may use many."""
    sched = WChoicesScheduler(16)
    rng = np.random.default_rng(1)
    keys = np.where(rng.random(10_000) < 0.5, 3, rng.integers(10, 500, 10_000))
    seen: dict[int, set] = {}
    for k in keys:
        seen.setdefault(int(k), set()).add(sched.route(int(k)))
    cold_fanout = max(len(v) for k, v in seen.items() if k != 3)
    assert cold_fanout <= 2, cold_fanout
    assert len(seen[3]) > 2  # the hot key did escape its two candidates


def test_w_choices_cold_fanout_survives_summary_saturation():
    """theta < 1/capacity: inherited SPACESAVING error must not fake a hot
    key, or evicted-and-reinserted cold sessions lose bounded fanout."""
    sched = WChoicesScheduler(600, capacity=256)  # theta=2/600 < 1/256
    rng = np.random.default_rng(3)
    keys = np.where(rng.random(30_000) < 0.3, 42, rng.integers(1000, 6000, 30_000))
    seen: dict[int, set] = {}
    for k in keys:
        seen.setdefault(int(k), set()).add(sched.route(int(k)))
    assert max(len(v) for k, v in seen.items() if k != 42) <= 2
    assert len(seen[42]) > 2


def test_w_choices_cold_routing_matches_potc():
    """Before any key crosses the threshold, W-Choices == PoTC decisions."""
    a, b = PoTCScheduler(8, seed=4), WChoicesScheduler(8, seed=4, theta=0.9)
    keys = np.random.default_rng(2).integers(0, 1000, 2000)
    assert [a.route(int(k)) for k in keys] == [b.route(int(k)) for k in keys]


def test_engine_greedy_generation():
    cfg = make_tiny(get_config("qwen2.5-3b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=64)
    prompts = jnp.asarray(np.random.default_rng(0).integers(1, 100, (2, 8)), jnp.int32)
    out = eng.generate(prompts, n_new=6)
    assert out.shape == (2, 14)
    np.testing.assert_array_equal(np.asarray(out[:, :8]), np.asarray(prompts))
    # deterministic
    out2 = eng.generate(prompts, n_new=6)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))
