"""Serving layer: PoTC replica scheduler balance + engine generation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, make_tiny
from repro.core import zipf_stream
from repro.models import init_params
from repro.serving import KGScheduler, PoTCScheduler, RoundRobinScheduler, ServeEngine


def _drive(sched, keys, costs):
    for k, c in zip(keys, costs):
        sched.route(int(k), float(c))
    loads = sched.loads
    return (loads.max() - loads.mean()) / max(loads.sum(), 1)


def test_potc_balances_hot_sessions():
    # p1 must stay below d/W for balance to be attainable (paper §5):
    # K=2000, z=1.1 gives p1 ~= 0.12 < 2/8
    keys = zipf_stream(20_000, 2_000, 1.1, seed=1)
    costs = np.ones(len(keys))
    f_potc = _drive(PoTCScheduler(8), keys, costs)
    f_kg = _drive(KGScheduler(8), keys, costs)
    assert f_potc < f_kg / 5, (f_potc, f_kg)
    assert f_potc < 0.02, f_potc


def test_potc_bounded_replica_fanout():
    """A session key only ever lands on <= 2 replicas (prefix-cache affinity)."""
    sched = PoTCScheduler(16)
    seen = {}
    keys = zipf_stream(5_000, 50, 1.0, seed=2)
    for k in keys:
        r = sched.route(int(k))
        seen.setdefault(int(k), set()).add(r)
    assert max(len(v) for v in seen.values()) <= 2


def test_complete_decrements():
    s = PoTCScheduler(4)
    r = s.route(123, cost=10.0)
    s.complete(r, cost=10.0)
    assert s.loads.sum() == 0


def test_round_robin_uniform():
    s = RoundRobinScheduler(5)
    for i in range(100):
        s.route(i)
    assert s.loads.max() - s.loads.min() <= 1


def test_engine_greedy_generation():
    cfg = make_tiny(get_config("qwen2.5-3b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=64)
    prompts = jnp.asarray(np.random.default_rng(0).integers(1, 100, (2, 8)), jnp.int32)
    out = eng.generate(prompts, n_new=6)
    assert out.shape == (2, 14)
    np.testing.assert_array_equal(np.asarray(out[:, :8]), np.asarray(prompts))
    # deterministic
    out2 = eng.generate(prompts, n_new=6)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))
