"""Per-worker capacity weighting: the cross-cutting contracts (PR 9).

The capacity extension (arXiv 1705.09073) touches every routing layer —
LoadLedger, all registered policies, all registered partitioners, the Pallas
kernels, the sharded router — and its safety story is a single invariant:

  *capacities=None and uniform capacities are BIT-EXACT to the unweighted
  path, on every registered entry point.*

That is what makes the feature free to adopt: turning it on with a uniform
vector changes nothing, and the weighted path only ever reroutes when the
vector says workers genuinely differ.  This module sweeps the registries so
a future capacity-aware implementation cannot register itself without
inheriting the differentials, and pins the two boundary semantics:

  * zero capacity == dead for host policies and the ledger (a worker that
    can do no work never wins an argmin), while device-backed policies
    REJECT non-positive capacities (the kernels divide by them);
  * elastic rescale (serving.sim.Autoscaler) conserves work — every request
    is completed or shed, and the ledger drains to exactly zero.
"""
import inspect

import numpy as np
import pytest

from repro.core import (
    PARTITIONERS,
    ROUTING_POLICIES,
    LoadLedger,
    capacity_imbalance_fraction,
    make_policy,
    zipf_stream,
)
from repro.serving import Autoscaler, PoTCScheduler, simulate_serving

N = 8
CAPS = np.array([1.0, 2.0, 4.0, 1.0, 2.0, 4.0, 1.0, 2.0])


def _keys(m=3_000, seed=0):
    return zipf_stream(m, 300, 1.3, seed=seed)


def _capacity_partitioners():
    """Registered partitioners that accept a capacities vector."""
    return [
        (name, fn) for name, fn in PARTITIONERS.items()
        if "capacities" in inspect.signature(fn).parameters
    ]


def _partition(fn, keys, **kw):
    sig = inspect.signature(fn).parameters
    if "emulate" in sig:  # sharded variants: force the 1-device ref path
        kw.setdefault("emulate", True)
    if "n_keys" in sig:  # potc_static_partition sizes its key table up front
        kw.setdefault("n_keys", int(np.max(keys)) + 1)
    return np.asarray(fn(keys, N, **kw))


# ---------------------------------------------------------------------------
# uniform capacities are bit-exact to the unweighted path, everywhere
# ---------------------------------------------------------------------------

def test_every_registered_partitioner_is_capacity_aware():
    """The registry sweep below must cover the full registry: any
    partitioner registered without a capacities parameter is a hole in the
    capacity story (kg/sg route capacity-blind by *algorithm* — they still
    take and ignore-or-use the argument uniformly)."""
    missing = [n for n, f in PARTITIONERS.items()
               if "capacities" not in inspect.signature(f).parameters]
    assert missing == ["kg", "sg"], missing


@pytest.mark.parametrize("name,fn", _capacity_partitioners())
def test_partitioner_uniform_capacity_bit_exact(name, fn):
    keys = _keys()
    base = _partition(fn, keys)
    unif = _partition(fn, keys, capacities=np.full(N, 1.0))
    np.testing.assert_array_equal(base, unif, err_msg=name)


@pytest.mark.parametrize("name,fn", _capacity_partitioners())
def test_partitioner_heterogeneous_capacity_valid(name, fn):
    """Weighted assignments stay in range and the capacity vector reaches
    the argmin: on a skewed pool some messages must move."""
    keys = _keys()
    base = _partition(fn, keys)
    het = _partition(fn, keys, capacities=CAPS)
    assert het.min() >= 0 and het.max() < N
    if name != "potc":  # potc samples d random candidates; loads only
        assert (het != base).any(), f"{name}: capacities had no effect"


@pytest.mark.parametrize("pname", sorted(ROUTING_POLICIES))
def test_policy_uniform_capacity_bit_exact(pname):
    keys = _keys(2_000)
    base = np.asarray(make_policy(pname, N).route_batch(keys))
    unif = np.asarray(
        make_policy(pname, N).route_batch(keys, capacities=np.full(N, 2.0))
    )
    np.testing.assert_array_equal(base, unif, err_msg=pname)


# ---------------------------------------------------------------------------
# zero capacity == dead (host), rejected (device)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pname", sorted(ROUTING_POLICIES))
def test_zero_capacity_worker_gets_no_traffic(pname):
    caps = CAPS.copy()
    caps[3] = 0.0
    policy = make_policy(pname, N)
    if not policy.per_request:  # device-backed: kernels divide by capacity
        with pytest.raises(ValueError, match="strictly positive"):
            policy.route_batch(_keys(512), capacities=caps)
        return
    a = np.asarray(policy.route_batch(_keys(2_000), capacities=caps))
    assert not (a == 3).any(), f"{pname} routed to a zero-capacity worker"


def test_ledger_zero_capacity_is_dead():
    led = LoadLedger(4, capacities=[1.0, 0.0, 2.0, 1.0])
    assert list(led.live_mask()) == [True, False, True, True]
    led.kill(0)
    assert list(led.live_mask()) == [False, False, True, True]
    led.revive(0)
    assert list(led.live_mask()) == [True, False, True, True]


@pytest.mark.parametrize("bad", [
    [1.0, 2.0],                   # wrong shape
    [1.0, -1.0, 1.0, 1.0],        # negative
    [1.0, float("nan"), 1.0, 1.0],
    [1.0, float("inf"), 1.0, 1.0],
])
def test_ledger_rejects_malformed_capacities(bad):
    with pytest.raises(ValueError):
        LoadLedger(4, capacities=bad)


def test_ledger_normalized_loads_and_imbalance():
    led = LoadLedger(3, capacities=[1.0, 2.0, 4.0])
    for r, c in ((0, 1.0), (1, 2.0), (2, 4.0)):  # exactly proportional
        led.acquire(r, c)
    np.testing.assert_allclose(led.normalized_loads(), [1.0, 1.0, 1.0])
    assert led.imbalance() == pytest.approx(0.0)
    led.acquire(0, 1.0)  # overload the slow worker
    assert led.imbalance() > 0.0


# ---------------------------------------------------------------------------
# the metric
# ---------------------------------------------------------------------------

def test_capacity_imbalance_zero_iff_proportional():
    assign = np.repeat(np.arange(3), [100, 200, 400])
    assert capacity_imbalance_fraction(
        assign, [1.0, 2.0, 4.0]) == pytest.approx(0.0)
    assert capacity_imbalance_fraction(assign, [1.0, 1.0, 1.0]) > 0.0


def test_capacity_imbalance_uniform_matches_relative_imbalance():
    rng = np.random.default_rng(0)
    assign = rng.integers(0, 5, size=4_000)
    loads = np.bincount(assign, minlength=5)
    expect = (loads.max() - loads.mean()) / loads.mean()
    got = capacity_imbalance_fraction(assign, np.ones(5))
    assert got == pytest.approx(expect)


# ---------------------------------------------------------------------------
# elastic rescale conserves work and drains clean
# ---------------------------------------------------------------------------

def _wave_costs(m):
    costs = np.ones(m)
    costs[m // 3: 2 * m // 3] = 2.5
    return costs


def test_autoscaler_rescale_conserves_and_drains():
    m = 6_000
    asc = Autoscaler(min_replicas=3, max_replicas=N, initial=3,
                     high=3.0, low=0.5, check_every=m // 100,
                     cooldown=m // 40)
    sched = PoTCScheduler(N, seed=0)
    res = simulate_serving(sched, _keys(m, seed=1), costs=_wave_costs(m),
                           utilization=0.85, autoscaler=asc)
    # conservation: nothing lost across every kill/revive transition
    assert res.completed + res.shed == m
    # the strict ledger drains to exactly zero after the tail drain
    np.testing.assert_array_equal(sched.ledger.loads, np.zeros(N))
    # the wave actually exercised both directions
    ups = [e for e in res.scale_events if e[1] == 1]
    downs = [e for e in res.scale_events if e[1] == -1]
    assert ups and downs
    # pool size stays within the configured band at every event
    size = asc.initial
    for _, d, _ in res.scale_events:
        size += d
        assert asc.min_replicas <= size <= asc.max_replicas
    # every request completed on a replica that existed
    assert res.assign.min() >= 0 and res.assign.max() < N


def test_autoscaler_with_heterogeneous_capacities():
    m = 4_000
    caps = CAPS.copy()
    asc = Autoscaler(min_replicas=2, max_replicas=N, initial=2,
                     high=3.0, low=0.5, check_every=m // 100,
                     cooldown=m // 50)
    sched = PoTCScheduler(N, seed=0, capacities=caps)
    res = simulate_serving(sched, _keys(m, seed=2), costs=_wave_costs(m),
                           utilization=0.85, autoscaler=asc)
    assert res.completed + res.shed == m
    np.testing.assert_array_equal(sched.ledger.loads, np.zeros(N))


def test_autoscaler_never_revives_zero_capacity_replica():
    m = 3_000
    caps = np.array([1.0, 1.0, 1.0, 0.0, 1.0, 1.0, 1.0, 1.0])
    asc = Autoscaler(min_replicas=2, max_replicas=7, initial=2,
                     high=2.0, low=0.5, check_every=m // 100,
                     cooldown=m // 50)
    sched = PoTCScheduler(N, seed=0, capacities=caps)
    res = simulate_serving(sched, _keys(m, seed=3), costs=_wave_costs(m),
                           utilization=0.9, autoscaler=asc)
    assert res.completed + res.shed == m
    assert not (res.assign == 3).any()
    assert all(r != 3 for _, _, r in res.scale_events)


def test_autoscaler_max_replicas_bounded_by_eligible():
    caps = np.array([1.0, 1.0, 0.0, 1.0])
    sched = PoTCScheduler(4, seed=0, capacities=caps)
    asc = Autoscaler(min_replicas=1, max_replicas=4, initial=1)
    with pytest.raises(ValueError, match="positive-capacity"):
        simulate_serving(sched, _keys(500), autoscaler=asc)


@pytest.mark.parametrize("kw", [
    dict(min_replicas=0, max_replicas=4),
    dict(min_replicas=3, max_replicas=2),
    dict(min_replicas=1, max_replicas=4, initial=5),
    dict(min_replicas=1, max_replicas=4, high=1.0, low=1.0),
    dict(min_replicas=1, max_replicas=4, check_every=0),
    dict(min_replicas=1, max_replicas=4, cooldown=-1),
])
def test_autoscaler_rejects_malformed_config(kw):
    with pytest.raises(ValueError):
        Autoscaler(**kw)


def test_uniform_capacity_serving_bit_exact():
    """The whole serving stack — scheduler, ledger, simulator service rates,
    sampling — reproduces the unweighted run exactly at uniform capacity."""
    m = 3_000
    keys = _keys(m, seed=4)
    base = simulate_serving(PoTCScheduler(N, seed=0), keys)
    unif = simulate_serving(
        PoTCScheduler(N, seed=0, capacities=np.full(N, 1.0)), keys)
    np.testing.assert_array_equal(base.assign, unif.assign)
    np.testing.assert_array_equal(base.latency, unif.latency)
    np.testing.assert_array_equal(base.sample_imbalance,
                                  unif.sample_imbalance)
    assert base.makespan == unif.makespan
