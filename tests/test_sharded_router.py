"""Sharded router (parallel/sharded_router.py, DESIGN.md §6.1).

The load-bearing invariants:

  * n_shards=1, sync_period=1 is BIT-EXACT to the single-core Pallas routers
    (adaptive_route / w_route) — the differential that pins the sharded scan
    to the shared block-greedy core;
  * the psum load-sync conserves mass: after the final epoch every shard's
    loads row equals the global assignment histogram (loads are integer
    counts in f32, so reduction order cannot matter);
  * on a stream whose hot keys concentrate in one shard's slice (sorted
    keys = heterogeneous substreams), final imbalance is monotone in
    sync_period — staleness costs balance;
  * the shard_map program matches the vmap+sum oracle bit-exactly on a real
    8-device mesh (subprocess, slow).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import zipf_stream
from repro.core.estimation import W_SENTINEL
from repro.core.partitioners import (
    _head_flags,
    pkg_sharded_partition,
    w_choices_sharded_partition,
)
from repro.core.routing import make_policy
from repro.kernels.adaptive_route import adaptive_route, w_route
from repro.launch.mesh import make_stream_mesh
from repro.parallel.sharded_router import (
    ref_sharded_route,
    routed_step_roofline,
    sharded_route,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
W = 16
N = 1024


def _keys(n=N, seed=0, z=1.4):
    return jnp.asarray(zipf_stream(n, 200, z, seed=seed))


def _w_ncand(keys, d=2):
    flags = _head_flags(np.asarray(keys), W, d, None, 1024, 8)
    return jnp.asarray(
        np.where(flags != 0, np.int32(W_SENTINEL), np.int32(d)).astype(np.int32)
    )


# ---------------------------------------------------------------------------
# differential: 1 shard + sync_period=1 == the single-core kernels
# ---------------------------------------------------------------------------


def test_one_shard_sync1_bit_exact_pkg():
    keys = _keys()
    a, loads = ref_sharded_route(keys, None, W, d_max=2, n_shards=1,
                                 sync_period=1)
    nc = jnp.full((N,), 2, jnp.int32)
    a_k, l_k = adaptive_route(keys, nc, W, d_max=2, chunk=N, block=128,
                              interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(a_k))
    np.testing.assert_array_equal(np.asarray(loads), np.asarray(l_k[-1]))


def test_one_shard_sync1_bit_exact_d_choices():
    keys = _keys(seed=1)
    nc = jnp.asarray(
        np.random.default_rng(0).integers(1, 5, N).astype(np.int32)
    )
    a, loads = ref_sharded_route(keys, nc, W, d_max=4, n_shards=1,
                                 sync_period=1)
    a_k, l_k = adaptive_route(keys, nc, W, d_max=4, chunk=N, block=128,
                              interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(a_k))
    np.testing.assert_array_equal(np.asarray(loads), np.asarray(l_k[-1]))


def test_one_shard_sync1_bit_exact_w_choices():
    keys = _keys(seed=2, z=1.8)
    nc = _w_ncand(keys)
    a, loads = ref_sharded_route(keys, nc, W, d_max=2, n_shards=1,
                                 sync_period=1, w_mode=True)
    flags = (np.asarray(nc) == int(W_SENTINEL)).astype(np.int32)
    a_k, l_k = w_route(keys, jnp.asarray(flags), W, d=2, chunk=N, block=128,
                       interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(a_k))
    np.testing.assert_array_equal(np.asarray(loads), np.asarray(l_k[-1]))


def test_shard_map_equals_ref_on_one_device():
    # the shard_map program itself (1-device mesh) vs the vmap+sum oracle
    keys = _keys(seed=3)
    for sync in (1, 4):
        a_s, l_s = sharded_route(keys, None, W, n_shards=1, sync_period=sync)
        a_r, l_r = ref_sharded_route(keys, None, W, n_shards=1,
                                     sync_period=sync)
        np.testing.assert_array_equal(np.asarray(a_s), np.asarray(a_r))
        np.testing.assert_array_equal(np.asarray(l_s), np.asarray(l_r))


# ---------------------------------------------------------------------------
# load-sync conservation + staleness tradeoff
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_shards,sync", [(2, 1), (4, 4), (8, 16)])
def test_load_sync_conservation(n_shards, sync):
    n = n_shards * sync * 128 * 2  # two epochs
    keys = _keys(n, seed=4, z=1.8)
    nc = _w_ncand(keys)
    a, loads = ref_sharded_route(keys, nc, W, n_shards=n_shards,
                                 sync_period=sync, w_mode=True)
    a_np = np.asarray(a)
    assert a_np.shape == (n,) and a_np.min() >= 0 and a_np.max() < W
    hist = np.bincount(a_np, minlength=W).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(loads), hist)


def test_imbalance_monotone_in_sync_period_on_hetero_shards():
    # sorted keys concentrate the zipf head in one shard's contiguous slice;
    # the rarer the sync, the longer the other shards under-serve the head
    # workers and the worse the final imbalance.
    n = 8 * 64 * 128
    keys_np = np.sort(zipf_stream(n, 1_000, 1.8, seed=5))
    keys = jnp.asarray(keys_np)
    flags = _head_flags(keys_np, 32, 2, None, 1024, 8)
    nc = jnp.asarray(np.where(flags != 0, np.int32(W_SENTINEL),
                              np.int32(2)).astype(np.int32))
    imb = []
    for sync in (1, 4, 16):
        a, _ = ref_sharded_route(keys, nc, 32, n_shards=8, sync_period=sync,
                                 w_mode=True)
        h = np.bincount(np.asarray(a), minlength=32)
        imb.append(float(h.max() - h.mean()) / n)
    for lo, hi in zip(imb, imb[1:]):
        assert hi >= lo - 1e-4, imb
    assert imb[-1] > imb[0], imb


# ---------------------------------------------------------------------------
# partitioner / policy surface
# ---------------------------------------------------------------------------


def test_partitioner_padding_prefix_stable():
    # single shard: the padded tail rides at the END of the shard, so the
    # real prefix of a longer stream routes identically — scatter-index
    # recovery must not scramble assignments.
    keys = _keys(1280, seed=6)
    a_short = np.asarray(pkg_sharded_partition(keys[:1000], W, n_shards=1))
    a_long = np.asarray(pkg_sharded_partition(keys, W, n_shards=1))
    assert a_short.shape == (1000,)
    np.testing.assert_array_equal(a_short, a_long[:1000])


def test_partitioner_multi_shard_emulated():
    keys = zipf_stream(5000, 300, 1.6, seed=7)
    a = np.asarray(w_choices_sharded_partition(keys, W, n_shards=4,
                                               sync_period=2, emulate=True))
    b = np.asarray(w_choices_sharded_partition(keys, W, n_shards=4,
                                               sync_period=2, emulate=True))
    assert a.shape == (5000,) and a.min() >= 0 and a.max() < W
    np.testing.assert_array_equal(a, b)


def test_sharded_policy_matches_partitioner():
    pol = make_policy("w_choices_sharded", W, n_shards=2, sync_period=4)
    keys = zipf_stream(4096, 300, 1.6, seed=8)
    a_pol = pol.route_batch(keys)
    a_part = np.asarray(w_choices_sharded_partition(
        keys, W, d=pol.d, seed=pol.seed, theta=pol.theta,
        capacity=pol.capacity, min_count=pol.min_count, n_shards=2,
        sync_period=4, block=pol.block,
    ))
    np.testing.assert_array_equal(a_pol, a_part)


def test_make_stream_mesh_rejects_oversubscription():
    too_many = jax.local_device_count() + 1
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        make_stream_mesh(too_many)


def test_routed_step_roofline_report():
    rep = routed_step_roofline(W, n_shards=1, sync_period=4, n_epochs=2)
    assert rep["flops_per_device"] > 0 and rep["hbm_bytes_per_device"] > 0
    assert rep["roofline"]["step_lower_bound_s"] > 0
    assert rep["collective_bytes_per_epoch"] >= 0
    assert rep["collective_bytes_per_device"] == (
        rep["collective_bytes_per_epoch"] * rep["n_epochs"]
    )


# ---------------------------------------------------------------------------
# real 8-device mesh (subprocess, slow)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_shard_map_matches_ref_on_eight_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import zipf_stream
    from repro.core.estimation import W_SENTINEL
    from repro.core.partitioners import _head_flags
    from repro.launch.mesh import make_stream_mesh
    from repro.parallel.sharded_router import ref_sharded_route, sharded_route

    assert jax.local_device_count() == 8
    mesh = make_stream_mesh(8)
    n, W = 8 * 4 * 128 * 2, 32
    keys_np = zipf_stream(n, 500, 1.8, seed=0)
    flags = _head_flags(keys_np, W, 2, None, 1024, 8)
    nc = jnp.asarray(np.where(flags != 0, np.int32(W_SENTINEL),
                              np.int32(2)).astype(np.int32))
    keys = jnp.asarray(keys_np)
    for sync in (1, 4):
        a_s, l_s = sharded_route(keys, nc, W, n_shards=8, sync_period=sync,
                                 w_mode=True, mesh=mesh)
        a_r, l_r = ref_sharded_route(keys, nc, W, n_shards=8,
                                     sync_period=sync, w_mode=True)
        np.testing.assert_array_equal(np.asarray(a_s), np.asarray(a_r))
        np.testing.assert_array_equal(np.asarray(l_s), np.asarray(l_r))
        hist = np.bincount(np.asarray(a_s), minlength=W).astype(np.float32)
        np.testing.assert_array_equal(np.asarray(l_s), hist)
    print("8-device sharded router OK")
    """
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
