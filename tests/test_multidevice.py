"""Multi-device integration (subprocess with 8 host devices): sharded train
step on the production sharding plan, compressed-DP step, and a smoke of the
dry-run cell builder.  Kept in subprocesses so the main test process stays on
the default 1-device backend."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert res.returncode == 0, res.stdout + "\n" + res.stderr


@pytest.mark.slow
def test_sharded_train_step_runs_and_matches_single_device():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config, make_tiny, TrainConfig
    from repro.models import init_params
    from repro.optim import adamw_init
    from repro.train import make_train_step
    from repro.parallel.sharding import make_plan, param_shardings, make_sharder

    cfg = make_tiny(get_config("qwen2.5-3b"))
    tcfg = TrainConfig(total_steps=10, warmup_steps=2)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    opt = adamw_init(params)
    batch = {"tokens": jax.random.randint(key, (8, 64), 0, cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0, cfg.vocab_size)}

    # single device reference
    step0 = jax.jit(make_train_step(cfg, tcfg))
    p_ref, _, m_ref = step0(params, opt, batch, jnp.int32(0))

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    plan = make_plan(cfg, mesh)
    sh = make_sharder(cfg, mesh, plan, "train", 8)
    pspecs = param_shardings(cfg, mesh, plan)
    named = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspecs,
                                   is_leaf=lambda x: isinstance(x, P))
    rep = NamedSharding(mesh, P())
    o_sh = {"m": named, "v": named, "count": rep}
    bspec = {k: NamedSharding(mesh, P("data", None)) for k in batch}
    step = jax.jit(make_train_step(cfg, tcfg, sh=sh, grad_shardings=named),
                   in_shardings=(named, o_sh, bspec, rep),
                   out_shardings=(named, o_sh, rep))
    p_sh, _, m_sh = step(params, opt, batch, jnp.int32(0))
    # loss identical up to bf16/reduction noise
    assert abs(float(m_ref["loss"]) - float(m_sh["loss"])) < 0.05, (m_ref, m_sh)
    for a, b in zip(jax.tree_util.tree_leaves(p_ref), jax.tree_util.tree_leaves(p_sh)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-2)
    print("sharded step OK")
    """)


@pytest.mark.slow
def test_compressed_dp_step_close_to_exact():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config, make_tiny, TrainConfig
    from repro.models import init_params
    from repro.optim import adamw_init
    from repro.train import make_train_step
    from repro.train.loop import make_dp_train_step

    cfg = make_tiny(get_config("qwen2.5-3b"))
    tcfg = TrainConfig(total_steps=10, warmup_steps=2)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (8, 64), 0, cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0, cfg.vocab_size)}

    mesh = jax.make_mesh((8,), ("pod",))
    step, init_fn = make_dp_train_step(cfg, tcfg, mesh, dp_axis="pod")
    opt = init_fn(params)
    p1, o1, m1 = step(params, opt, batch, jnp.int32(0))

    ref = jax.jit(make_train_step(cfg, tcfg))
    p_ref, _, m_ref = ref(params, adamw_init(params), batch, jnp.int32(0))
    assert abs(float(m1["loss"]) - float(m_ref["loss"])) < 0.05
    # int8-compressed grads: params close but not identical
    deltas = [float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
              for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p_ref))]
    assert max(deltas) < 5e-2, max(deltas)
    print("compressed DP OK")
    """)


@pytest.mark.slow
def test_decode_cell_builder_smoke():
    _run("""
    import jax
    from repro.configs import get_config, make_tiny
    from repro.configs.base import ShapeConfig
    from repro.launch.dryrun import build_cell
    cfg = make_tiny(get_config("gemma3-4b"))
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    for shape in [ShapeConfig("train", "train", 64, 8), ShapeConfig("decode", "decode", 128, 8)]:
        fn, args = build_cell(cfg, shape, mesh)
        fn.lower(*args).compile()
    print("cell builder OK")
    """)


@pytest.mark.slow
def test_pipeline_parallel_parity():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.parallel.pipeline import make_pipelined_fn

    S, M, mb, D = 4, 8, 2, 16
    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (S, D, D)) / np.sqrt(D)

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, D))
    mesh = jax.make_mesh((4,), ("stage",))
    piped = make_pipelined_fn(stage_fn, mesh, S)
    out_p = piped(ws, x)

    # sequential reference
    ref = x
    for s in range(S):
        ref = jnp.tanh(ref @ ws[s])
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(ref), atol=1e-5)

    # differentiable end-to-end
    def loss(ws):
        return (piped(ws, x) ** 2).mean()
    g = jax.grad(loss)(ws)
    assert np.isfinite(np.asarray(g)).all() and np.abs(np.asarray(g)).sum() > 0
    print("pipeline parity OK")
    """)
