"""Paper-core behavior: the imbalance ordering of Table 2 and the key
properties of each partitioner."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    avg_imbalance_fraction,
    final_imbalance_fraction,
    hash_choices,
    hash_partition,
    keys_per_worker,
    off_greedy_partition,
    on_greedy_partition,
    pkg_partition,
    pkg_partition_batched,
    potc_static_partition,
    shuffle_partition,
    zipf_stream,
)

M, K, W = 120_000, 10_000, 10


@pytest.fixture(scope="module")
def stream():
    return zipf_stream(M, K, z=1.0, seed=7)


def test_shuffle_imbalance_at_most_one(stream):
    a = np.asarray(shuffle_partition(jnp.asarray(stream), W))
    loads = np.bincount(a, minlength=W)
    assert loads.max() - loads.mean() <= 1.0


def test_pkg_beats_hashing_by_orders_of_magnitude(stream):
    kg = avg_imbalance_fraction(np.asarray(hash_partition(jnp.asarray(stream), W)), W)
    pkg = avg_imbalance_fraction(np.asarray(pkg_partition(jnp.asarray(stream), W)), W)
    assert pkg < kg / 100, (pkg, kg)


def test_table2_ordering(stream):
    """H > PoTC > On-Greedy >= PKG (paper Table 2's qualitative ordering)."""
    ks = jnp.asarray(stream)
    h = final_imbalance_fraction(np.asarray(hash_partition(ks, W)), W)
    potc = final_imbalance_fraction(np.asarray(potc_static_partition(ks, W, K)), W)
    ong = final_imbalance_fraction(np.asarray(on_greedy_partition(ks, W, K)), W)
    pkg = final_imbalance_fraction(np.asarray(pkg_partition(ks, W)), W)
    assert h > potc > pkg
    assert ong > pkg
    offg = final_imbalance_fraction(np.asarray(off_greedy_partition(ks, W, K)), W)
    assert h > offg


def test_key_splitting_bounds_workers_per_key(stream):
    """Each key is handled by at most d workers (the memory argument, §3.1)."""
    ks = jnp.asarray(stream)
    for d in (2, 3):
        a = np.asarray(pkg_partition(ks, W, d=d))
        cand = np.asarray(hash_choices(ks, W, d=d))
        assert (a[:, None] == cand).any(axis=1).all()
        pairs = np.unique(np.stack([stream.astype(np.int64), a]), axis=1)
        per_key = np.bincount(pairs[0], minlength=K)
        assert per_key.max() <= d


def test_pkg_memory_between_kg_and_sg(stream):
    ks = jnp.asarray(stream)
    kg_mem = keys_per_worker(stream, np.asarray(hash_partition(ks, W)), W).sum()
    pkg_mem = keys_per_worker(stream, np.asarray(pkg_partition(ks, W)), W).sum()
    sg_mem = keys_per_worker(stream, np.asarray(shuffle_partition(ks, W)), W).sum()
    n_keys = len(np.unique(stream))
    assert kg_mem == n_keys
    assert kg_mem <= pkg_mem <= 2 * n_keys
    assert pkg_mem < sg_mem


def test_batched_greedy_close_to_sequential(stream):
    """TPU vector-batched PKG stays within ~an order of the exact scan."""
    ks = jnp.asarray(stream)
    exact = avg_imbalance_fraction(np.asarray(pkg_partition(ks, W)), W)
    for block in (64, 128, 256):
        bat = avg_imbalance_fraction(
            np.asarray(pkg_partition_batched(ks, W, block=block)), W
        )
        assert bat < 20 * max(exact, 1e-6) + 1e-4, (block, bat, exact)


def test_weighted_pkg(stream):
    w = (stream % 5 + 1).astype(np.int32)
    a = np.asarray(pkg_partition(jnp.asarray(stream), W, weights=jnp.asarray(w)))
    loads = np.bincount(a, weights=w, minlength=W)
    frac = (loads.max() - loads.mean()) / w.sum()
    assert frac < 1e-3


def test_hash_partition_deterministic_and_in_range(stream):
    ks = jnp.asarray(stream)
    a1 = np.asarray(hash_partition(ks, W))
    a2 = np.asarray(hash_partition(ks, W))
    assert (a1 == a2).all()
    assert a1.min() >= 0 and a1.max() < W
    # same key always to the same worker
    for key in np.unique(stream[:50]):
        assert len(np.unique(a1[stream == key])) == 1


def test_stream_generators_match_paper_stats():
    """Table-1 stats: matched p1 and the balanceability regime of §5."""
    from repro.core import graph_edge_stream, matched_trace_stream
    from repro.core.streams import PAPER_DATASETS

    wp = PAPER_DATASETS["WP"].generate(seed=0, scale=0.01)
    counts = np.bincount(wp)
    p1 = counts.max() / len(wp)
    assert 0.07 < p1 < 0.12, p1  # target 9.32%

    src, dst = graph_edge_stream(100_000, 50_000, 200_000, seed=1)
    p1_dst = np.bincount(dst).max() / len(dst)
    assert p1_dst < 0.02, p1_dst  # LJ-like light head (paper: 0.29%)
