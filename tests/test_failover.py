"""Fault tolerance: a run interrupted by failure and resumed from checkpoint
produces exactly the same final state as an uninterrupted run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import TrainConfig, get_config, make_tiny
from repro.data import PKGDataPipeline, SyntheticCorpus
from repro.models import init_params
from repro.optim import adamw_init
from repro.train import SimulatedFailure, TrainingHarness, make_train_step


def _setup(tmp_path, tag, fail_at=None):
    cfg = make_tiny(get_config("qwen2.5-3b"))
    tcfg = TrainConfig(total_steps=20, warmup_steps=2, learning_rate=1e-3)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    pipe = PKGDataPipeline(
        batch_size=2, seq_len=32, vocab_size=cfg.vocab_size,
        corpus=SyntheticCorpus(cfg.vocab_size, n_keys=64, seed=5), seed=5,
    )
    mgr = CheckpointManager(str(tmp_path / tag), keep=5)
    step = jax.jit(make_train_step(cfg, tcfg))
    h = TrainingHarness(step, pipe, mgr, checkpoint_every=4, fail_at_step=fail_at)
    return h, params, opt


def test_failover_restart_matches_uninterrupted(tmp_path):
    # uninterrupted reference
    h_ref, p0, o0 = _setup(tmp_path, "ref")
    p_ref, _, hist_ref = h_ref.run(p0, o0, target_step=10)

    # interrupted at step 6 (after the step-4 checkpoint), then restarted
    h1, p1, o1 = _setup(tmp_path, "ft", fail_at=6)
    with pytest.raises(SimulatedFailure):
        h1.run(p1, o1, target_step=10)
    h2, p2, o2 = _setup(tmp_path, "ft")  # fresh process, same ckpt dir
    p_ft, _, hist_ft = h2.run(p2, o2, target_step=10)

    for a, b in zip(jax.tree_util.tree_leaves(p_ref), jax.tree_util.tree_leaves(p_ft)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    # replayed losses match the reference run for the overlapping steps
    np.testing.assert_allclose(hist_ref[4:], hist_ft, atol=1e-5)


def test_loss_decreases(tmp_path):
    h, p, o = _setup(tmp_path, "desc")
    _, _, hist = h.run(p, o, target_step=20)
    assert np.mean(hist[-5:]) < np.mean(hist[:5]), hist
