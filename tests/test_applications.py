"""§4 applications: heavy hitters error bounds and naïve Bayes exactness."""
import jax.numpy as jnp
import numpy as np

from repro.core import hash_partition, pkg_partition, shuffle_partition, zipf_stream
from repro.core.applications import (
    SpaceSaving,
    StreamingNaiveBayes,
    distributed_heavy_hitters,
)

W, CAP = 8, 256


def test_spacesaving_exact_when_under_capacity():
    ss = SpaceSaving(100)
    keys = np.repeat(np.arange(50), np.arange(1, 51))
    ss.offer_many(keys)
    assert ss.max_error() == 0
    assert ss.estimate(49) == 50 and ss.estimate(0) == 1


def test_spacesaving_overestimates_only():
    keys = zipf_stream(50_000, 5_000, 1.2, seed=0)
    ss = SpaceSaving(CAP)
    ss.offer_many(keys)
    true = np.bincount(keys, minlength=5_000)
    for k, est in ss.top_k(20):
        assert est >= true[k]
        assert est - true[k] <= ss.max_error()


def test_heavy_hitters_pkg_merges_two_summaries_sg_merges_w():
    """§4.2: error bound sums per-summary errors a key's summaries touch —
    ≤2 under PKG, W under SG — and PKG's top-k recall matches or beats SG."""
    keys = zipf_stream(200_000, 20_000, 1.1, seed=1)
    true = np.bincount(keys, minlength=20_000)
    true_top = set(np.argsort(-true)[:20])
    ks = jnp.asarray(keys)

    def recall(assign):
        topk, err, loads = distributed_heavy_hitters(
            keys, np.asarray(assign), W, CAP
        )
        got = {k for k, _ in topk}
        return len(got & true_top) / 20, err, loads

    r_pkg, e_pkg, l_pkg = recall(pkg_partition(ks, W))
    r_sg, e_sg, _ = recall(shuffle_partition(ks, W))
    r_kg, e_kg, l_kg = recall(hash_partition(ks, W))
    assert r_pkg >= 0.9
    assert r_pkg >= r_sg - 1e-9
    # key-splitting: a key's estimate involves <=2 summaries vs W under SG;
    # the summed worst-case bound reflects it
    assert e_pkg <= e_sg
    # and PKG balances where KG does not
    assert (l_pkg.max() - l_pkg.mean()) < 0.2 * (l_kg.max() - l_kg.mean())


def test_naive_bayes_pkg_model_is_exact():
    """PKG partial counters merge to the exact sequential model (monoid)."""
    rng = np.random.default_rng(0)
    vocab, n_classes, n_docs = 500, 3, 300
    class_words = [rng.permutation(vocab)[:50] for _ in range(n_classes)]
    docs, labels = [], []
    for _ in range(n_docs):
        c = int(rng.integers(n_classes))
        words = rng.choice(class_words[c], size=20)
        docs.append(words.astype(np.int32))
        labels.append(c)

    # sequential reference
    ref = StreamingNaiveBayes(n_classes)
    for d, l in zip(docs, labels):
        ref.observe(d, l)

    # PKG-partitioned: route each word occurrence; workers hold partials
    flat = np.concatenate(docs)
    flat_labels = np.concatenate([[l] * len(d) for d, l in zip(docs, labels)])
    assign = np.asarray(pkg_partition(jnp.asarray(flat), W))
    workers = [StreamingNaiveBayes(n_classes) for _ in range(W)]
    for w, word, lab in zip(assign, flat, flat_labels):
        key = (int(word), int(lab))
        workers[w].word_class[key] = workers[w].word_class.get(key, 0) + 1
        workers[w].class_counts[lab] += 1
    merged = StreamingNaiveBayes(n_classes)
    for w in workers:
        merged.merge_counts(w)

    assert merged.word_class == ref.word_class
    np.testing.assert_array_equal(merged.class_counts, ref.class_counts)
    # per-word state is split over at most 2 workers (memory claim §3.1)
    per_word = {}
    for w, word in zip(assign, flat):
        per_word.setdefault(int(word), set()).add(int(w))
    assert max(len(v) for v in per_word.values()) <= 2
    # and the merged model classifies like the reference
    test_doc = rng.choice(class_words[1], size=20).astype(np.int32)
    assert merged.predict(test_doc, vocab) == ref.predict(test_doc, vocab) == 1
