"""Online (scan-carry) SPACESAVING: error bounds, decay, and the
offline-vs-online agreement regressions (DESIGN.md SS3.3 "Online estimation").

The hypothesis property test checks the classic SPACESAVING guarantees hold
for the array-state implementation on *drifting* streams: estimates are upper
bounds, over-estimation never exceeds total/capacity (the m/k bound), and the
error-corrected count is a lower bound.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.core import (
    SpaceSavingTracker,
    adaptive_d,
    adaptive_d_counts,
    d_choices_partition,
    drift_stream,
    head_threshold,
    online_d_choices_partition,
    online_head_tables,
    online_ss_estimate,
    online_ss_from_tracker,
    online_ss_init,
    online_ss_update,
    zipf_stream,
)
from repro.core.metrics import avg_imbalance_fraction


def _run_tracker(keys, capacity):
    state = online_ss_init(capacity)
    return lax.scan(
        lambda s, k: (online_ss_update(s, k), None), state,
        jnp.asarray(keys, jnp.int32),
    )[0]


def _assert_ss_bounds(state, keys, capacity):
    true = np.bincount(np.asarray(keys), minlength=int(np.max(keys)) + 1)
    ks = np.asarray(state.keys)
    counts = np.asarray(state.counts)
    errors = np.asarray(state.errors)
    total = int(state.total)
    assert total == len(keys)
    live = counts > 0
    assert live.sum() <= capacity
    est = counts[live]
    tc = true[ks[live]]
    assert (est >= tc).all(), "estimates must be upper bounds"
    assert (est - tc <= total / capacity).all(), "m/k over-estimation bound"
    assert (est - errors[live] <= tc).all(), "error-corrected count is a lower bound"


@pytest.mark.parametrize("capacity", [8, 64])
@pytest.mark.parametrize("z", [0.8, 1.8])
def test_online_ss_bounds_on_drifting_streams(capacity, z):
    keys = drift_stream(3_000, 300, z, half_life=500, seed=z > 1)
    _assert_ss_bounds(_run_tracker(keys, capacity), keys, capacity)


def test_online_ss_bounds_property():
    """Hypothesis sweep over stream shapes (drift rate, skew, capacity)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=15, deadline=None)
    @hyp.given(
        n_keys=st.integers(5, 200),
        z=st.floats(0.0, 2.5),
        half_life=st.integers(50, 2_000),
        capacity=st.integers(2, 48),
        seed=st.integers(0, 5),
    )
    def check(n_keys, z, half_life, capacity, seed):
        keys = drift_stream(
            800, n_keys, z, half_life=half_life,
            rotate_top=min(8, n_keys), seed=seed,
        )
        _assert_ss_bounds(_run_tracker(keys, capacity), keys, capacity)

    check()


def test_online_matches_python_tracker_totals():
    """Array state and dict tracker agree on totals and on clear head keys."""
    keys = zipf_stream(20_000, 2_000, 1.6, seed=4)
    state = _run_tracker(keys, 128)
    tracker = SpaceSavingTracker(128)
    for k in keys:  # element-wise: identical offer schedule to the scan
        tracker.offer(int(k))
    assert int(state.total) == tracker.total
    ids, _ = tracker.head_keys(0.02)
    # a dict-tracker head key's true count is >= (theta - 1/cap) * m, and the
    # array state's estimate upper-bounds the true count
    floor = (0.02 - 1.0 / 128) * len(keys)
    for k in ids:
        assert int(online_ss_estimate(state, int(k))) >= floor


def test_online_ss_decay_tracks_rotating_head():
    """With windowed decay the head table follows the drift; without, the
    stale head lingers.  Checked via the per-block tables the kernel consumes."""
    m, n_keys, W = 16_384, 2_000, 100
    rng = np.random.default_rng(0)
    half = m // 2
    a = np.where(rng.random(half) < 0.4, 7, rng.integers(0, n_keys, half))
    b = np.where(rng.random(half) < 0.4, 1_313, rng.integers(0, n_keys, half))
    keys = jnp.asarray(np.concatenate([a, b]), jnp.int32)
    tk, tn = online_head_tables(
        keys, block=128, capacity=64, n_workers=W, d_max=16,
        decay_period=1_024,
    )
    last_k, last_n = np.asarray(tk[-1]), np.asarray(tn[-1])
    head_now = set(last_k[last_n > 2].tolist())
    assert 1_313 in head_now, "new head must be detected online"
    assert 7 not in head_now, "decayed summary must forget the old head"
    # without decay the old head's accumulated mass keeps it flagged
    tk2, tn2 = online_head_tables(keys, block=128, capacity=64, n_workers=W, d_max=16)
    stale_k, stale_n = np.asarray(tk2[-1]), np.asarray(tn2[-1])
    assert 7 in set(stale_k[stale_n > 2].tolist())


def test_adaptive_d_counts_integer_exact():
    """A ceil boundary where float64 and integer arithmetic disagree:
    p = 350/10000 = 0.035 -> slack*p*W = 7 exactly, so d(k) = 7 — but 0.035
    is not binary-representable and the float path rounds the product just
    above 7, giving ceil = 8.  The online and offline variants both must use
    the integer rule or frozen-carry differential equality breaks."""
    assert int(adaptive_d_counts(np.asarray([350]), 10_000, 100)[0]) == 7
    assert int(adaptive_d(np.asarray([350 / 10_000.0]), 100)[0]) == 8  # the trap
    # jnp and numpy paths agree everywhere
    counts = np.arange(0, 2_000, 7, dtype=np.int64)
    a = adaptive_d_counts(counts, 20_000, 100, d_base=2, d_max=16)
    b = adaptive_d_counts(jnp.asarray(counts, jnp.int32), jnp.int32(20_000), 100,
                          d_base=2, d_max=16)
    np.testing.assert_array_equal(a, np.asarray(b))


def test_offline_and_online_d_choices_agree_on_stationary_streams():
    """Satellite regression: same stream, no drift -> the online variant's
    balance matches the offline pre-pass (and bit-exactly so when the carry
    is warm-started and frozen; see test_partitioner_invariants)."""
    W = 100
    keys = zipf_stream(25_000, 5_000, 1.8, seed=11)
    off = avg_imbalance_fraction(
        np.asarray(d_choices_partition(keys, W, capacity=256)), W
    )
    on = avg_imbalance_fraction(
        np.asarray(online_d_choices_partition(keys, W, capacity=256)), W
    )
    assert on <= 1.2 * off + 1e-4, (on, off)


def test_online_ss_from_tracker_roundtrip():
    keys = zipf_stream(10_000, 1_000, 1.5, seed=2)
    tracker = SpaceSavingTracker(64)
    tracker.update(keys)
    state = online_ss_from_tracker(tracker, 64)
    assert int(state.total) == tracker.total
    for k, c in tracker._ss.counts.items():
        assert int(online_ss_estimate(state, k)) == c


def test_tracker_decay_windowed_mode():
    tracker = SpaceSavingTracker(32)
    tracker.update(np.full(1_000, 5, np.int64))
    assert tracker.is_head(5, theta=0.5)
    tracker.decay(0.5)
    assert tracker.total == 500
    assert tracker._ss.counts[5] == 500
    # decay keeps fractions, so head status is unchanged on a stable stream
    assert tracker.is_head(5, theta=0.5)
    # a one-element tail entry decays away entirely
    tracker.offer(9)
    tracker.decay(0.5)
    assert 9 not in tracker._ss.counts


def test_head_threshold_is_balanceability_bound():
    assert head_threshold(100, 2) == pytest.approx(0.02)
