"""Checkpoint manager: roundtrip, keep-k GC, async, elastic device_put."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {
        "params": {"w": jax.random.normal(k1, (8, 16)), "b": jnp.zeros(16)},
        "opt": {"m": jax.random.normal(k2, (8, 16)), "count": jnp.int32(7)},
        "data": {"chunk_index": np.int64(42), "buffer": np.arange(10, dtype=np.int32)},
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree(jax.random.PRNGKey(0))
    mgr.save(10, tree, blocking=True)
    out = mgr.restore(tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(out)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree(jax.random.PRNGKey(1))
    for s in (1, 2, 3, 4):
        mgr.save(s, tree, blocking=True)
    assert mgr.all_steps() == [3, 4]


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = _tree(jax.random.PRNGKey(2))
    mgr.save(5, tree, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 5
    out = mgr.restore(tree)
    np.testing.assert_allclose(
        np.asarray(out["params"]["w"]), np.asarray(tree["params"]["w"])
    )


def test_atomic_no_partial_dirs(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree(jax.random.PRNGKey(3)), blocking=True)
    names = os.listdir(tmp_path)
    assert all(not n.startswith(".tmp") for n in names)


def test_elastic_restore_with_shardings(tmp_path):
    """Restore re-places leaves with explicit shardings (elastic restart)."""
    mgr = CheckpointManager(str(tmp_path), keep=1)
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, tree, blocking=True)
    dev = jax.devices()[0]
    shardings = {"w": jax.sharding.SingleDeviceSharding(dev)}
    out = mgr.restore(tree, shardings=shardings)
    assert out["w"].sharding == shardings["w"]
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(tree["w"]))


def test_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore({"x": jnp.zeros(1)})
