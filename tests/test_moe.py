"""MoE layer: dispatch correctness, PKG-PoTC balance advantage, capacity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, make_tiny
from repro.models.moe import expert_load_stats, moe_apply, moe_defs, route
from repro.parallel.spec import materialize


def _cfg(router="topk_aux", **kw):
    base = make_tiny(get_config("olmoe-1b-7b"))
    return dataclasses.replace(base, router=router, **kw)


def _params(cfg, key):
    return materialize(moe_defs(cfg), key)


def test_moe_output_shape_and_finite():
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    p = _params(cfg, key)
    x = jax.random.normal(key, (2, 32, cfg.d_model), jnp.float32)
    y, aux = moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(aux) >= 0


def test_aux_loss_zero_for_pkg():
    cfg = _cfg("pkg_potc")
    key = jax.random.PRNGKey(1)
    p = _params(cfg, key)
    x = jax.random.normal(key, (2, 32, cfg.d_model))
    _, aux = moe_apply(p, x, cfg)
    assert float(aux) == 0.0


def test_pkg_router_balances_better_than_topk():
    """Skewed router logits: PKG max/mean expert load << vanilla top-k."""
    cfg_tk = _cfg("topk_aux")
    cfg_pkg = _cfg("pkg_potc")
    key = jax.random.PRNGKey(2)
    p = _params(cfg_tk, key)
    # make one expert dominate by biasing the router weights
    p["router"] = p["router"].at[:, 0].add(1.0)
    x = jax.random.normal(key, (8, 128, cfg_tk.d_model))
    x2d = x.reshape(-1, cfg_tk.d_model)
    idx_tk, _, _ = route(p, x2d, cfg_tk)
    idx_pkg, _, _ = route(p, x2d, cfg_pkg)
    _, max_tk = expert_load_stats(idx_tk, cfg_tk.n_experts)
    _, max_pkg = expert_load_stats(idx_pkg, cfg_pkg.n_experts)
    assert float(max_pkg) < float(max_tk), (float(max_pkg), float(max_tk))
    assert float(max_pkg) < 1.8


def test_pkg_slots_distinct_experts():
    cfg = _cfg("pkg_potc", top_k=2)
    key = jax.random.PRNGKey(3)
    p = _params(cfg, key)
    x2d = jax.random.normal(key, (256, cfg.d_model))
    idx, gates, _ = route(p, x2d, cfg)
    assert idx.shape == (256, 2)
    assert bool((idx[:, 0] != idx[:, 1]).all())  # slots draw disjoint rank pairs
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-3)


def test_capacity_drops_tokens_when_overloaded():
    cfg = _cfg("topk_aux", capacity_factor=0.25)
    key = jax.random.PRNGKey(4)
    p = _params(cfg, key)
    p["router"] = p["router"].at[:, 0].add(8.0)  # everything to expert 0
    x = jax.random.normal(key, (2, 64, cfg.d_model))
    y, _ = moe_apply(p, x, cfg)
    # most tokens dropped -> output mostly zeros but finite
    assert bool(jnp.isfinite(y).all())
    frac_zero = float((jnp.abs(y) < 1e-9).mean())
    assert frac_zero > 0.3


def test_moe_gradients_flow_to_all_parts():
    cfg = _cfg("topk_aux")
    key = jax.random.PRNGKey(5)
    p = _params(cfg, key)
    x = jax.random.normal(key, (2, 64, cfg.d_model))

    def loss(p):
        y, aux = moe_apply(p, x, cfg)
        return (y.astype(jnp.float32) ** 2).mean() + aux

    g = jax.grad(loss)(p)
    for name, leaf in g.items():
        assert bool(jnp.any(leaf != 0)), name
