"""Discrete-event serving simulator: completion accounting, the prefix-cache
model, and per-tenant SLO reporting (DESIGN.md §8)."""
import numpy as np
import pytest

from repro.core.metrics import tenant_imbalance_report
from repro.core.routing import make_policy
from repro.core.streams import multi_tenant_stream, zipf_stream
from repro.serving import PolicyScheduler, simulate_serving


def _sched(name, n, **kw):
    return PolicyScheduler(make_policy(name, n, d=2, seed=0, **kw))


def test_sim_delivers_every_completion_and_drains():
    """Every routed request completes exactly once; after the drain the
    scheduler ledger is empty — outstanding work really is outstanding."""
    keys = zipf_stream(3_000, 200, 1.2, seed=0)
    sched = _sched("potc", 8)
    res = simulate_serving(sched, keys, utilization=0.8)
    assert res.completed == len(keys)
    assert sched.loads.sum() == 0.0
    assert (sched.loads >= 0).all()
    assert res.makespan > 0


def test_sim_costs_flow_through_ledger():
    keys = np.arange(100, dtype=np.int32)
    costs = np.full(100, 2.5)
    sched = _sched("rr", 4)
    res = simulate_serving(sched, keys, costs=costs, utilization=0.5)
    assert res.completed == 100
    assert sched.loads.sum() == 0.0
    assert res.makespan >= 2.5  # at least one full service time


def test_sim_outstanding_tracks_queue_not_cumulative():
    """At low utilization outstanding work stays tiny even though cumulative
    routed work grows without bound — the launch/serve.py fix."""
    keys = zipf_stream(5_000, 500, 0.8, seed=1)
    sched = _sched("rr", 8)
    res = simulate_serving(sched, keys, utilization=0.3)
    # queue depth bounded => peak outstanding is orders below total work
    # (the old serve.py printed cumulative loads, which would be ~m/n here)
    assert res.peak_outstanding < 0.05 * len(keys)


def test_prefix_cache_hit_rates_order_kg_over_rr():
    """Sticky routing keeps sessions' prefixes warm; spraying does not."""
    keys = zipf_stream(8_000, 400, 1.4, seed=2)
    r_kg = simulate_serving(_sched("kg", 16), keys, cache_capacity=32)
    r_rr = simulate_serving(_sched("rr", 16), keys, cache_capacity=32)
    assert r_kg.hit_rate > r_rr.hit_rate
    assert r_kg.session_fanout_max == 1
    assert r_rr.session_fanout_max == 16


def test_prefix_cache_lru_capacity_matters():
    """Shrinking the cache lowers the hit-rate (capacity misses appear)."""
    keys = zipf_stream(8_000, 600, 1.2, seed=3)
    big = simulate_serving(_sched("kg", 4), keys, cache_capacity=256)
    tiny = simulate_serving(_sched("kg", 4), keys, cache_capacity=8)
    assert tiny.hit_rate < big.hit_rate


def test_sim_assignments_match_policy_under_no_queueing():
    """With utilization -> 0 every request completes before the next one
    arrives, so loads are always zero at decision time: load-oblivious
    policies (kg) give identical assignments to route_batch."""
    keys = zipf_stream(1_000, 100, 1.0, seed=4)
    res = simulate_serving(_sched("kg", 8), keys, utilization=0.01)
    np.testing.assert_array_equal(
        res.assign, make_policy("kg", 8, seed=0).route_batch(keys)
    )


def test_sim_validates_inputs():
    with pytest.raises(ValueError, match="costs length"):
        simulate_serving(_sched("rr", 4), np.arange(10), costs=np.ones(5))
    with pytest.raises(ValueError, match="utilization"):
        simulate_serving(_sched("rr", 4), np.arange(10), utilization=0.0)


# --- per-tenant SLO accounting ----------------------------------------------


def test_tenant_report_counts_violations():
    """Crafted assignment: tenant 0 all on one replica (gross violation),
    tenant 1 perfectly round-robin (no violation)."""
    m = 4_000
    tenants = np.arange(m) % 2
    # tenant 1 cycles all 8 replicas ((i//2) % 8 over odd i hits every value)
    assign = np.where(tenants == 0, 0, (np.arange(m) // 2) % 8).astype(np.int32)
    rep = tenant_imbalance_report(assign, tenants, 8, slo=0.05)
    assert rep["tenants"][0]["violated"]
    assert not rep["tenants"][1]["violated"]
    assert rep["tenants_violating"] == 1
    assert rep["tenants"][0]["checkpoint_violations"] > 0
    assert rep["tenants"][1]["checkpoint_violations"] == 0
    # tenant 0: replica 0 holds everything; avg_t I(t)/m averages the growing
    # prefix, so the fraction sits near (1 - 1/8) * mean(t)/m ~ 0.44
    assert rep["tenants"][0]["avg_imbalance_fraction"] > 0.3
    assert rep["tenants"][1]["avg_imbalance_fraction"] < 0.01


def test_tenant_report_shape_mismatch_raises():
    with pytest.raises(ValueError, match="shape mismatch"):
        tenant_imbalance_report(np.zeros(5, int), np.zeros(4, int), 2)


def test_sim_tenant_slo_w_choices_clean_kg_dirty():
    """The bench_serving acceptance story at test size: under multi-tenant
    skew at W >> hot sessions, KG violates tenant SLOs, W-Choices does not,
    and the tradeoff ordering holds."""
    keys, tenants = multi_tenant_stream(
        20_000, n_tenants=4, n_keys=2_000, z=1.6, weights=[4, 2, 1, 1], seed=0
    )
    out = {}
    for name in ("kg", "rr", "potc", "w_choices"):
        out[name] = simulate_serving(
            _sched(name, 100), keys, tenants=tenants, cache_capacity=64,
            slo=0.1,  # above the lightest tenant's small-sample noise floor
        )
    assert out["kg"].tenant_report["tenants_violating"] > 0
    assert out["w_choices"].tenant_report["tenants_violating"] == 0
    # hit-rate: kg > {w, potc} > rr ; imbalance: w < potc < kg
    assert out["kg"].hit_rate > out["w_choices"].hit_rate > out["rr"].hit_rate
    assert out["kg"].hit_rate > out["potc"].hit_rate > out["rr"].hit_rate
    assert (
        out["w_choices"].assign_imbalance
        < out["potc"].assign_imbalance
        < out["kg"].assign_imbalance
    )
