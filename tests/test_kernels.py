"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.estimation import W_SENTINEL, online_head_tables
from repro.core.streams import drift_stream, zipf_stream
from repro.kernels import ref
from repro.kernels.adaptive_route import (
    _waterfill_picks,
    adaptive_route,
    adaptive_route_online,
    w_route,
)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_pkg_dispatch import moe_adaptive_dispatch, moe_pkg_dispatch
from repro.kernels.pkg_route import pkg_route
from repro.kernels.rmsnorm import rmsnorm
from repro.models.moe import _pkg_choose, expert_head_tables


@pytest.mark.parametrize("n_workers", [5, 16, 50, 100])
@pytest.mark.parametrize("d", [2, 3])
def test_pkg_route_matches_ref(n_workers, d):
    keys = jnp.asarray(zipf_stream(4096, 777, 1.1, seed=n_workers))
    a_k, l_k = pkg_route(keys, n_workers, d=d, chunk=1024, block=128)
    a_r, l_r = ref.ref_pkg_route(keys, n_workers, d=d, chunk=1024, block=128)
    np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_r))
    np.testing.assert_allclose(np.asarray(l_k), np.asarray(l_r))


@pytest.mark.parametrize("chunk,block", [(512, 64), (2048, 256), (1024, 1024)])
def test_pkg_route_chunk_block_sweep(chunk, block):
    keys = jnp.asarray(zipf_stream(4096, 333, 1.4, seed=1))
    a_k, _ = pkg_route(keys, 12, chunk=chunk, block=block)
    a_r, _ = ref.ref_pkg_route(keys, 12, chunk=chunk, block=block)
    np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_r))


@pytest.mark.parametrize("n_workers", [16, 50, 100])
@pytest.mark.parametrize("d_max", [2, 4, 8])
def test_adaptive_route_matches_ref(n_workers, d_max):
    keys = jnp.asarray(zipf_stream(4096, 777, 1.6, seed=d_max))
    nc = jnp.asarray(
        np.random.default_rng(n_workers).integers(1, d_max + 1, 4096, dtype=np.int32)
    )
    a_k, l_k = adaptive_route(keys, nc, n_workers, d_max=d_max)
    a_r, l_r = ref.ref_adaptive_route(keys, nc, n_workers, d_max=d_max)
    np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_r))
    np.testing.assert_array_equal(np.asarray(l_k), np.asarray(l_r))


@pytest.mark.parametrize("chunk,block", [(512, 64), (2048, 256), (1024, 1024)])
def test_adaptive_route_chunk_block_sweep(chunk, block):
    keys = jnp.asarray(zipf_stream(4096, 333, 1.4, seed=1))
    nc = jnp.asarray(np.random.default_rng(2).integers(1, 5, 4096, dtype=np.int32))
    a_k, _ = adaptive_route(keys, nc, 12, d_max=4, chunk=chunk, block=block)
    a_r, _ = ref.ref_adaptive_route(keys, nc, 12, d_max=4, chunk=chunk, block=block)
    np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_r))


@pytest.mark.parametrize("n_workers", [16, 100])
@pytest.mark.parametrize("capacity", [32, 64])
def test_adaptive_route_online_matches_ref(n_workers, capacity):
    """Head-table kernel vs oracle, tables from the real online tracker."""
    keys = jnp.asarray(zipf_stream(4096, 777, 1.8, seed=capacity))
    tk, tn = online_head_tables(
        keys, block=128, capacity=capacity, n_workers=n_workers, d_max=8
    )
    a_k, l_k = adaptive_route_online(keys, tk, tn, n_workers, d_max=8)
    a_r, l_r = ref.ref_adaptive_route_online(keys, tk, tn, n_workers, d_max=8)
    np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_r))
    np.testing.assert_array_equal(np.asarray(l_k), np.asarray(l_r))


def test_adaptive_route_online_drift_decay_matches_ref():
    """Same contract under drift with the windowed (decayed) tracker."""
    keys = jnp.asarray(drift_stream(8192, 2_000, 1.8, half_life=2_048, seed=3))
    tk, tn = online_head_tables(
        keys, block=128, capacity=64, n_workers=100, d_max=8, decay_period=2_048
    )
    a_k, _ = adaptive_route_online(keys, tk, tn, 100, d_max=8)
    a_r, _ = ref.ref_adaptive_route_online(keys, tk, tn, 100, d_max=8)
    np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_r))


def test_adaptive_route_online_empty_table_is_pkg_route():
    """All-miss head tables (staleness degenerate case) reduce to plain PKG:
    a lookup miss yields d_base candidates and the seed family is prefix-
    stable, so assignments match pkg_route bit-exactly."""
    keys = jnp.asarray(zipf_stream(4096, 500, 1.2, seed=3))
    nblk = 4096 // 128
    tk = jnp.full((nblk, 32), -1, jnp.int32)
    tn = jnp.zeros((nblk, 32), jnp.int32)
    a_o, l_o = adaptive_route_online(keys, tk, tn, 16, d_base=2, d_max=4)
    a_p, l_p = pkg_route(keys, 16, d=2)
    np.testing.assert_array_equal(np.asarray(a_o), np.asarray(a_p))
    np.testing.assert_array_equal(np.asarray(l_o), np.asarray(l_p))


def test_adaptive_route_all_two_choices_is_pkg_route():
    """n_cand == 2 everywhere reduces to the plain PKG router bit-exactly."""
    keys = jnp.asarray(zipf_stream(4096, 500, 1.2, seed=3))
    nc = jnp.full(4096, 2, jnp.int32)
    a_a, l_a = adaptive_route(keys, nc, 16, d_max=4)
    a_p, l_p = pkg_route(keys, 16, d=2)
    np.testing.assert_array_equal(np.asarray(a_a), np.asarray(a_p))
    np.testing.assert_array_equal(np.asarray(l_a), np.asarray(l_p))


# ---------------------------------------------------------------------------
# W-Choices global-argmin path (DESIGN.md SS3.3 "In-kernel W-Choices")
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_workers", [7, 100, 150, 200])
def test_waterfill_picks_equal_sequential_argmin(n_workers):
    """The loop-free water-fill must reproduce 'argmin, add one' exactly —
    including lowest-index ties — for W not a power of two and W > the VPU
    lane width the reduction pads to."""
    rng = np.random.default_rng(n_workers)
    loads = rng.integers(0, 40, n_workers).astype(np.float32)
    picks = np.asarray(
        _waterfill_picks(jnp.asarray(loads)[None, :], n_workers=n_workers, block=96)
    )
    sim, cur = [], loads.copy()
    for _ in range(96):
        j = int(np.argmin(cur))
        sim.append(j)
        cur[j] += 1.0
    assert picks.tolist() == sim


@pytest.mark.parametrize("n_workers", [7, 50, 100, 200])
@pytest.mark.parametrize("d", [2, 4])
def test_w_route_matches_ref(n_workers, d):
    """Kernel vs oracle with random head flags: assignments AND loads bit-
    equal, across W not a power of two and W above the 128-lane block."""
    keys = jnp.asarray(zipf_stream(2048, 500, 1.6, seed=n_workers))
    flags = jnp.asarray(
        (np.random.default_rng(d).random(2048) < 0.25).astype(np.int32)
    )
    a_k, l_k = w_route(keys, flags, n_workers, d=d, chunk=1024, block=128)
    a_r, l_r = ref.ref_w_route(keys, flags, n_workers, d=d, chunk=1024, block=128)
    np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_r))
    np.testing.assert_array_equal(np.asarray(l_k), np.asarray(l_r))


def test_w_route_all_tail_is_pkg_route():
    """No head flags -> the sentinel path is never taken and the W router
    IS the plain PKG router, message for message."""
    keys = jnp.asarray(zipf_stream(2048, 500, 1.2, seed=3))
    flags = jnp.zeros(2048, jnp.int32)
    a_w, l_w = w_route(keys, flags, 16, d=2)
    a_p, l_p = pkg_route(keys, 16, d=2)
    np.testing.assert_array_equal(np.asarray(a_w), np.asarray(a_p))
    np.testing.assert_array_equal(np.asarray(l_w), np.asarray(l_p))


@pytest.mark.parametrize("n_workers", [13, 100])
def test_w_route_all_head_waterfills_perfectly(n_workers):
    """Every message head-flagged -> the whole chunk is one global water-fill:
    worker loads differ by at most 1, and the kernel still matches its
    oracle bit-exactly."""
    keys = jnp.asarray(zipf_stream(1024, 50, 1.5, seed=9))
    flags = jnp.ones(1024, jnp.int32)
    a_k, _ = w_route(keys, flags, n_workers, chunk=1024, block=128)
    a_r, _ = ref.ref_w_route(keys, flags, n_workers, chunk=1024, block=128)
    np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_r))
    loads = np.bincount(np.asarray(a_k), minlength=n_workers)
    assert loads.max() - loads.min() <= 1


def test_w_route_tie_break_deterministic_at_equal_loads():
    """From an all-zero loads row, the water-fill must cycle workers in
    ascending index order (argmin's first-index rule at every level) — the
    tie-break contract shared with w_choices_partition."""
    W = 16
    keys = jnp.asarray(zipf_stream(1024, 50, 1.5, seed=1))
    flags = jnp.ones(1024, jnp.int32)
    a, _ = w_route(keys, flags, W, chunk=1024, block=128)
    np.testing.assert_array_equal(
        np.asarray(a)[:128], np.arange(128, dtype=np.int32) % W
    )


def test_w_route_block1_equals_w_choices_partition():
    """THE differential contract: with block=1 (no staleness) and a single
    chunk, the in-kernel W-Choices path reproduces the sequential
    w_choices_partition bit-exactly given the same head set."""
    from repro.core.estimation import SpaceSavingTracker, head_threshold
    from repro.core.partitioners import _head_lookup, w_choices_partition

    W, cap = 100, 256
    keys_np = zipf_stream(2048, 500, 1.8, seed=5).astype(np.int32)
    tracker = SpaceSavingTracker(cap)
    tracker.update(keys_np)
    head_ids, _, _ = tracker.head_counts(head_threshold(W, 2), 8)
    assert len(head_ids) > 0, "stream must actually have head keys"
    flags = _head_lookup(
        keys_np.astype(np.int64), head_ids, np.ones(len(head_ids), np.int32), 0
    )
    a_seq = np.asarray(w_choices_partition(keys_np, W, capacity=cap))
    a_krn, _ = w_route(
        jnp.asarray(keys_np), jnp.asarray(flags), W, chunk=2048, block=1
    )
    np.testing.assert_array_equal(a_seq, np.asarray(a_krn))


def test_w_choices_kernel_partition_registered_and_bit_exact_at_block1():
    """The registered partitioner wraps the same contract end to end (its own
    tracker pre-pass included) and is reachable through PARTITIONERS."""
    from repro.core.partitioners import PARTITIONERS, w_choices_partition

    assert PARTITIONERS["w_choices_kernel"] is not None
    W, cap = 100, 256
    keys_np = zipf_stream(1500, 400, 1.8, seed=7).astype(np.int32)  # ragged m
    a_seq = np.asarray(w_choices_partition(keys_np, W, capacity=cap))
    a_krn = np.asarray(
        PARTITIONERS["w_choices_kernel"](
            keys_np, W, capacity=cap, chunk=1536, block=1
        )
    )
    np.testing.assert_array_equal(a_seq, a_krn)


@pytest.mark.parametrize("n_workers", [50, 100])
def test_adaptive_route_online_any_worker_matches_ref(n_workers):
    """Online W-Choices: sentinel head tables flow through _head_table_ncand
    unclipped and the kernel matches ref_w_route_online bit-exactly."""
    keys = jnp.asarray(drift_stream(4096, 800, 1.8, half_life=2048, seed=2))
    tk, tn = online_head_tables(
        keys, block=128, capacity=64, n_workers=n_workers, d=2, d_max=2,
        any_worker=True,
    )
    assert (np.asarray(tn) == int(W_SENTINEL)).any(), "no head slot emitted"
    a_k, l_k = adaptive_route_online(
        keys, tk, tn, n_workers, d_base=2, d_max=2, w_mode=True
    )
    a_r, l_r = ref.ref_w_route_online(keys, tk, tn, n_workers, d_base=2, d_max=2)
    np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_r))
    np.testing.assert_array_equal(np.asarray(l_k), np.asarray(l_r))


def test_w_mode_off_matches_on_without_sentinels():
    """w_mode is a perf switch, not a semantics switch: sentinel-free
    candidate counts route bit-identically with the W path compiled out,
    kernel and oracle both."""
    keys = jnp.asarray(zipf_stream(2048, 500, 1.4, seed=4))
    nc = jnp.asarray(np.random.default_rng(0).integers(1, 5, 2048, dtype=np.int32))
    a_on, l_on = adaptive_route(keys, nc, 32, d_max=4, w_mode=True)
    a_off, l_off = adaptive_route(keys, nc, 32, d_max=4, w_mode=False)
    np.testing.assert_array_equal(np.asarray(a_on), np.asarray(a_off))
    np.testing.assert_array_equal(np.asarray(l_on), np.asarray(l_off))
    r_on, _ = ref.ref_adaptive_route(keys, nc, 32, d_max=4, w_mode=True)
    r_off, _ = ref.ref_adaptive_route(keys, nc, 32, d_max=4, w_mode=False)
    np.testing.assert_array_equal(np.asarray(r_on), np.asarray(r_off))


@pytest.mark.parametrize("T,k,E,block", [(512, 1, 8, 128), (1024, 2, 16, 256), (2048, 8, 64, 512)])
def test_moe_pkg_dispatch_matches_ref(T, k, E, block):
    key = jax.random.PRNGKey(T + k)
    probs = jax.nn.softmax(jax.random.normal(key, (T, E)), -1)
    tv, ti = jax.lax.top_k(probs, 2 * k)
    cand = ti.reshape(T, k, 2).astype(jnp.int32)
    cg = tv.reshape(T, k, 2)
    i_k, g_k, l_k = moe_pkg_dispatch(cand, cg, E, block=block)
    i_r, g_r, l_r = ref.ref_moe_pkg_dispatch(cand, cg, E, block=block)
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_r))
    np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_r), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(l_k), np.asarray(l_r))


def test_moe_dispatch_balance_property():
    """PKG dispatch keeps the max-expert load near the mean."""
    key = jax.random.PRNGKey(0)
    T, E, k = 4096, 16, 2
    # adversarially skewed router: one expert dominates logits
    logits = jax.random.normal(key, (T, E)).at[:, 0].add(3.0)
    probs = jax.nn.softmax(logits, -1)
    tv, ti = jax.lax.top_k(probs, 2 * k)
    idx, _, loads = moe_pkg_dispatch(
        ti.reshape(T, k, 2).astype(jnp.int32), tv.reshape(T, k, 2), E
    )
    assert float(loads.max()) / (T * k / E) < 1.7
    naive = jnp.zeros(E).at[ti[:, :k].reshape(-1)].add(1.0)
    assert float(loads.max()) < float(naive.max())


def _moe_cands(key, T, E, k, width, skew=3.0):
    """Router-ranked candidates/gates (T, k, width) with a hot expert 0."""
    logits = jax.random.normal(key, (T, E)).at[:, 0].add(skew)
    probs = jax.nn.softmax(logits, -1)
    tv, ti = jax.lax.top_k(probs, width * k)
    return ti.reshape(T, k, width).astype(jnp.int32), tv.reshape(T, k, width)


@pytest.mark.parametrize(
    "T,k,E,block", [(512, 1, 8, 128), (1024, 2, 16, 256), (1024, 4, 64, 512)]
)
@pytest.mark.parametrize("w_mode,d_max", [(False, 4), (True, 2)])
def test_moe_adaptive_dispatch_matches_ref(T, k, E, block, w_mode, d_max):
    """Pallas adaptive dispatch vs the shared-core oracle, with REAL head
    tables from the preferred-expert stream: capped-count tables (d mode)
    and sentinel tables (w mode), idx + gates + loads bit-equal."""
    key = jax.random.PRNGKey(T + k + d_max)
    cand, cg = _moe_cands(key, T, E, k, d_max)
    tk, tn = expert_head_tables(
        cand[:, 0, 0], E, block, d_base=2, d_max=d_max, any_worker=w_mode
    )
    out_k = moe_adaptive_dispatch(
        cand, cg, tk, tn, E, d_base=2, d_max=d_max, block=block, w_mode=w_mode
    )
    out_r = ref.ref_moe_adaptive_dispatch(
        cand, cg, tk, tn, E, d_base=2, d_max=d_max, block=block, w_mode=w_mode
    )
    for a, b in zip(out_k, out_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_moe_dispatch_block1_matches_model_pkg_choose():
    """With block=1 (no staleness) the kernel, its oracle, and the model
    layer's _pkg_choose are the same sequential PoTC, token for token —
    the contract tying models/moe.py to the kernel substrate."""
    T, k, E = 256, 2, 8
    cand, cg = _moe_cands(jax.random.PRNGKey(9), T, E, k, 2)
    i_m, g_m = _pkg_choose(cand, cg, E, block=1)
    i_r, g_r, l_r = ref.ref_moe_pkg_dispatch(cand, cg, E, block=1)
    i_k, g_k, l_k = moe_pkg_dispatch(cand, cg, E, block=1)
    np.testing.assert_array_equal(np.asarray(i_m), np.asarray(i_r))
    np.testing.assert_array_equal(np.asarray(i_r), np.asarray(i_k))
    np.testing.assert_array_equal(np.asarray(g_m), np.asarray(g_r))
    np.testing.assert_array_equal(np.asarray(g_r), np.asarray(g_k))
    np.testing.assert_array_equal(np.asarray(l_r), np.asarray(l_k))
    # the model layer's int32 histogram is the f32 loads, exactly
    counts = np.bincount(np.asarray(i_m).reshape(-1), minlength=E)
    np.testing.assert_array_equal(counts, np.asarray(l_k).astype(np.int64))


def test_moe_adaptive_all_miss_table_is_pkg_dispatch():
    """All-miss head tables (the all-tail block): every token keeps its
    d_base=2 rank pair, so the W-mode adaptive dispatch IS plain PKG-PoTC
    dispatch bit-exactly — kernel and oracle both."""
    T, k, E, block = 1024, 2, 16, 256
    cand, cg = _moe_cands(jax.random.PRNGKey(4), T, E, k, 2)
    tk = jnp.full((T // block, E), -1, jnp.int32)
    tn = jnp.zeros((T // block, E), jnp.int32)
    out_a = moe_adaptive_dispatch(
        cand, cg, tk, tn, E, d_base=2, d_max=2, block=block, w_mode=True
    )
    out_p = moe_pkg_dispatch(cand, cg, E, block=block)
    out_r = ref.ref_moe_pkg_dispatch(cand, cg, E, block=block)
    for a, p, r in zip(out_a, out_p, out_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(p))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(r))


def test_moe_adaptive_all_head_waterfills_and_ties_ascend():
    """Every token prefers a sentinel-flagged expert -> the whole stream
    water-fills: final expert loads within 1 of each other; from zero loads
    the first block's picks cycle experts in ascending id order (argmin's
    first-index tie-break — tie-break determinism); spilled lanes keep
    their slot's top-ranked gate."""
    T, k, E, block = 512, 2, 8, 128
    key = jax.random.PRNGKey(6)
    runner = jax.random.randint(key, (T, k, 1), 0, E, jnp.int32)
    cand = jnp.concatenate([jnp.zeros((T, k, 1), jnp.int32), runner], -1)
    cg = jax.nn.softmax(jax.random.normal(key, (T, k, 2)), -1)
    tk = jnp.full((T // block, E), -1, jnp.int32).at[:, 0].set(0)
    tn = jnp.zeros((T // block, E), jnp.int32).at[:, 0].set(int(W_SENTINEL))
    idx, gates, loads = moe_adaptive_dispatch(
        cand, cg, tk, tn, E, d_base=2, d_max=2, block=block, w_mode=True
    )
    i_r, g_r, l_r = ref.ref_moe_adaptive_dispatch(
        cand, cg, tk, tn, E, d_base=2, d_max=2, block=block, w_mode=True
    )
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(i_r))
    np.testing.assert_array_equal(np.asarray(gates), np.asarray(g_r))
    loads = np.asarray(loads)
    assert loads.sum() == T * k
    assert loads.max() - loads.min() <= 1
    np.testing.assert_array_equal(
        np.asarray(idx).reshape(-1)[: block * k],
        np.arange(block * k, dtype=np.int32) % E,
    )
    np.testing.assert_array_equal(np.asarray(gates), np.asarray(cg[:, :, 0]))


@pytest.mark.parametrize(
    "B,S,T,H,Kv,hd,causal,window",
    [
        (2, 256, 256, 4, 2, 64, True, 0),
        (1, 128, 384, 8, 8, 64, True, 128),
        (2, 256, 256, 4, 1, 32, False, 0),
        (1, 256, 256, 6, 2, 80, True, 0),  # danube-like hd=80
        (1, 128, 512, 4, 4, 128, True, 256),
    ],
)
def test_flash_attention_matches_ref(B, S, T, H, Kv, hd, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(S + T), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, T, Kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, Kv, hd), jnp.float32)
    o_k = flash_attention(q, k, v, causal=causal, window=window)
    o_r = ref.ref_flash_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=2e-5)


def test_flash_attention_bf16():
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (2, 256, 4, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (2, 256, 2, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (2, 256, 2, 64), jnp.bfloat16)
    o_k = flash_attention(q, k, v).astype(jnp.float32)
    o_r = ref.ref_flash_attention(q, k, v).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=3e-2)


@pytest.mark.parametrize("shape", [(8, 128), (3, 77, 256), (2, 4, 64, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(shape, dtype):
    ks = jax.random.split(jax.random.PRNGKey(shape[-1]), 2)
    x = jax.random.normal(ks[0], shape, dtype)
    w = jax.random.normal(ks[1], (shape[-1],), jnp.float32) * 0.2
    o_k = rmsnorm(x, w).astype(jnp.float32)
    o_r = ref.ref_rmsnorm(x, w).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=1e-5 if dtype == jnp.float32 else 2e-2)
