"""Empirical checks of Theorem 5.1: with p1 <= 1/(5n) and m >= n^2,
I(m) = O(m/n) for d >= 2 while d = 1 carries an extra ln n / ln ln n factor.
Uses the paper's own tight-case distribution (uniform over 5n keys)."""
import jax.numpy as jnp
import numpy as np

from repro.core import pkg_partition, uniform_stream


def _imbalance_fraction(n_workers, d, m, seed=0):
    keys = uniform_stream(m, 5 * n_workers, seed=seed)
    a = np.asarray(pkg_partition(jnp.asarray(keys), n_workers, d=d, seed=seed))
    loads = np.bincount(a, minlength=n_workers)
    return (loads.max() - loads.mean()) / m


def test_greedy2_linear_in_m_over_n():
    """I(m)*n/m stays O(1) for d=2 across n (the Theorem 5.1 upper bound)."""
    for n in (8, 16, 32):
        m = max(40 * n * n, 20_000)
        frac = _imbalance_fraction(n, d=2, m=m)
        assert frac * n < 1.0, (n, frac)


def test_greedy1_worse_than_greedy2():
    n = 16
    m = 50_000
    f1 = np.mean([_imbalance_fraction(n, 1, m, s) for s in range(3)])
    f2 = np.mean([_imbalance_fraction(n, 2, m, s) for s in range(3)])
    assert f1 > 2 * f2, (f1, f2)


def test_imbalance_grows_linearly_when_p1_large():
    """When p1 > 2/n no scheme can avoid Omega(m) imbalance (§5.1 example)."""
    n = 16
    keys = np.zeros(20_000, dtype=np.int32)  # single key: p1 = 1
    a = np.asarray(pkg_partition(jnp.asarray(keys), n))
    loads = np.bincount(a, minlength=n)
    frac = (loads.max() - loads.mean()) / len(keys)
    # two bins share all the mass: imbalance fraction -> 1/2 - 1/n
    assert frac > 0.25
