"""The routing-policy substrate: hash unification, ledger accounting, the
per-request-adapter == route_batch differential, and the W-Choices edge
policy's cold/hot contracts (ISSUE 5 satellites)."""
import numpy as np
import pytest

from repro.core.hashing import hash_choices, hash_choices_np
from repro.core.routing import (
    ROUTING_POLICIES,
    LoadLedger,
    PoTCPolicy,
    RoundRobinPolicy,
    WChoicesPolicy,
    make_policy,
)
from repro.core.streams import multi_tenant_stream, zipf_stream
from repro.serving import PolicyScheduler

HOST_POLICIES = ["kg", "rr", "potc", "w_choices"]


# --- hash unification -------------------------------------------------------


def test_numpy_hash_bit_identical_to_device_hash():
    """hash_choices_np is the schedulers' hash; it must equal the device
    family exactly or edge and core disagree on candidate replicas."""
    keys = zipf_stream(4096, 1000, 1.2, seed=3)
    for d, seed, n in [(1, 0, 7), (2, 4, 16), (5, 99, 101)]:
        np.testing.assert_array_equal(
            hash_choices_np(keys, n, d=d, seed=seed),
            np.asarray(hash_choices(keys, n, d=d, seed=seed)),
        )


def test_scalar_hash_matches_vector_hash():
    got = [int(hash_choices_np(k, 16, d=1, seed=5)[0]) for k in range(64)]
    want = hash_choices_np(np.arange(64), 16, d=1, seed=5)[:, 0].tolist()
    assert got == want


# --- LoadLedger -------------------------------------------------------------


def test_ledger_acquire_release_clamps():
    led = LoadLedger(4)
    led.acquire(1, 5.0)
    led.acquire(1, 2.0)
    assert led.loads[1] == 7.0
    led.release(1, 3.0)
    assert led.loads[1] == 4.0
    led.release(1, 99.0)  # over-release clamps at zero
    assert led.loads[1] == 0.0
    assert (led.loads >= 0).all()


def test_ledger_imbalance_fraction():
    led = LoadLedger(4)
    for r, c in [(0, 8.0), (1, 4.0), (2, 2.0), (3, 2.0)]:
        led.acquire(r, c)
    assert led.imbalance() == pytest.approx(8.0 - 4.0)
    assert led.imbalance_fraction() == pytest.approx(4.0 / 16.0)


# --- differential: per-request adapter == route_batch ------------------------


@pytest.mark.parametrize("name", HOST_POLICIES)
def test_adapter_bit_identical_to_route_batch(name):
    """ISSUE satellite: a fresh PolicyScheduler driven request by request
    (no completions) must reproduce route_batch exactly — same policy code,
    same ledger arithmetic, same stream."""
    keys, _ = multi_tenant_stream(6_000, n_tenants=3, n_keys=400, z=1.5, seed=2)
    batch = make_policy(name, 24, d=2, seed=7).route_batch(keys)
    sched = PolicyScheduler(make_policy(name, 24, d=2, seed=7))
    per_request = np.array([sched.route(int(k)) for k in keys], np.int32)
    np.testing.assert_array_equal(batch, per_request)
    np.testing.assert_allclose(
        sched.loads, np.bincount(batch, minlength=24).astype(np.float64)
    )


@pytest.mark.parametrize("name", ["potc", "w_choices"])
def test_adapter_differential_with_costs(name):
    keys = zipf_stream(3_000, 300, 1.3, seed=5)
    costs = np.random.default_rng(0).lognormal(0.0, 0.5, len(keys))
    batch = make_policy(name, 10, d=2, seed=1).route_batch(keys, costs)
    sched = PolicyScheduler(make_policy(name, 10, d=2, seed=1))
    per = np.array(
        [sched.route(int(k), float(c)) for k, c in zip(keys, costs)], np.int32
    )
    np.testing.assert_array_equal(batch, per)


def test_route_batch_is_deterministic_across_calls():
    """route_batch resets estimator state: two calls, identical output."""
    keys = zipf_stream(2_000, 100, 1.5, seed=1)
    pol = make_policy("w_choices", 16, d=2, seed=0)
    a, b = pol.route_batch(keys), pol.route_batch(keys)
    np.testing.assert_array_equal(a, b)
    rr = make_policy("rr", 5, seed=3)
    np.testing.assert_array_equal(rr.route_batch(keys), rr.route_batch(keys))


# --- individual policy contracts --------------------------------------------


def test_kg_matches_single_choice_hash():
    keys = zipf_stream(1_000, 200, 1.0, seed=0)
    out = make_policy("kg", 13, seed=2).route_batch(keys)
    np.testing.assert_array_equal(
        out, hash_choices_np(keys, 13, d=1, seed=2)[:, 0]
    )


def test_rr_uniform_and_seed_offsets():
    out = make_policy("rr", 5, seed=0).route_batch(np.zeros(100, np.int32))
    counts = np.bincount(out, minlength=5)
    assert counts.max() - counts.min() <= 1
    # the seed is honored as a start offset: different seeds, shifted cycles
    a = RoundRobinPolicy(7, seed=1).route_batch(np.zeros(14, np.int32))
    b = RoundRobinPolicy(7, seed=2).route_batch(np.zeros(14, np.int32))
    assert a[0] != b[0] or not np.array_equal(a, b)
    assert (np.diff(a) % 7 == 1).all()  # still cyclic


def test_potc_fanout_bounded_by_d():
    keys = zipf_stream(5_000, 60, 1.0, seed=4)
    for d in (2, 3):
        out = PoTCPolicy(16, d=d, seed=0).route_batch(keys)
        fan = {}
        for k, r in zip(keys, out):
            fan.setdefault(int(k), set()).add(int(r))
        assert max(len(v) for v in fan.values()) <= d


# --- W-Choices edge policy (ISSUE satellite: cold->hot transition) ----------


def test_w_choices_cold_to_hot_transition_routes_globally():
    """A key starts cold (PoTC candidates only) and, once its tracked
    fraction clears theta, routes to the globally least-loaded replica."""
    n = 16
    pol = WChoicesPolicy(n, d=2, seed=0, min_count=8)
    pol.reset()
    led = LoadLedger(n)
    hot = 7
    cand = set(int(c) for c in pol.candidates(hot))
    # interleave the hot key with uniform cold traffic
    rng = np.random.default_rng(0)
    replicas_seen = []
    was_hot = []
    for i in range(4_000):
        k = hot if rng.random() < 0.6 else int(rng.integers(100, 5000))
        c = pol.decide(k, led.loads)
        led.acquire(c, 1.0)
        if k == hot:
            replicas_seen.append(c)
            was_hot.append(pol.is_hot(hot))
    # cold phase: only the two hash candidates; hot phase: global argmin
    first_hot = was_hot.index(True)
    assert first_hot > 0, "key must start cold (min_count floor)"
    assert set(replicas_seen[:first_hot]) <= cand
    assert len(set(replicas_seen)) > 2, "hot key escaped its candidates"


def test_w_choices_cold_keys_stay_within_d_replicas():
    rng = np.random.default_rng(1)
    keys = np.where(rng.random(10_000) < 0.5, 3, rng.integers(10, 500, 10_000))
    out = WChoicesPolicy(16, d=2, seed=0).route_batch(keys)
    fan = {}
    for k, r in zip(keys, out):
        fan.setdefault(int(k), set()).add(int(r))
    assert max(len(v) for k, v in fan.items() if k != 3) <= 2
    assert len(fan[3]) > 2


def test_w_choices_batch_beats_potc_past_balanceability_limit():
    rng = np.random.default_rng(0)
    keys = np.where(rng.random(20_000) < 0.6, 7, rng.integers(100, 5000, 20_000))

    def frac(assign, n):
        loads = np.bincount(assign, minlength=n).astype(float)
        return (loads.max() - loads.mean()) / loads.sum()

    f_w = frac(WChoicesPolicy(16, d=2, seed=0).route_batch(keys), 16)
    f_p = frac(PoTCPolicy(16, d=2, seed=0).route_batch(keys), 16)
    assert f_w < f_p / 5
    assert f_w < 0.01


# --- registry / device-backed policies --------------------------------------


def test_make_policy_unknown_name_raises():
    with pytest.raises(ValueError, match="unknown routing policy"):
        make_policy("nope", 4)


def test_registry_names_match_classes():
    for name, cls in ROUTING_POLICIES.items():
        assert cls.name == name


def test_device_policy_rejects_per_request_and_costs():
    pol = make_policy("w_choices_kernel", 8)
    with pytest.raises(NotImplementedError):
        pol.decide(1, np.zeros(8))
    with pytest.raises(ValueError, match="batch-only"):
        PolicyScheduler(pol)
    with pytest.raises(ValueError, match="unit-cost"):
        pol.route_batch(np.arange(8), costs=np.full(8, 2.0))


def test_device_w_policy_matches_host_w_partitioner():
    """The registered device-backed W policy rides the Pallas kernel; at
    block=1 the kernel is bit-exact to w_choices_partition, which shares its
    head set with the host batch path."""
    from repro.core.partitioners import w_choices_partition

    keys = zipf_stream(1_024, 200, 1.6, seed=0)
    dev = make_policy(
        "w_choices_kernel", 50, d=2, seed=0, block=1, capacity=1024
    )
    np.testing.assert_array_equal(
        dev.route_batch(keys),
        np.asarray(w_choices_partition(keys, 50, d=2, seed=0, capacity=1024)),
    )


def test_device_d_policy_matches_host_d_partitioner():
    """adaptive_route at block=1 == d_choices_partition (same pre-pass)."""
    from repro.core.partitioners import d_choices_partition

    keys = zipf_stream(1_024, 200, 1.6, seed=1)
    dev = make_policy(
        "d_choices_kernel", 50, d=2, seed=0, d_max=8, block=1, capacity=1024
    )
    np.testing.assert_array_equal(
        dev.route_batch(keys),
        np.asarray(
            d_choices_partition(keys, 50, d=2, d_max=8, seed=0, capacity=1024)
        ),
    )
