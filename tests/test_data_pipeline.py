"""PKG data pipeline: determinism, checkpoint/resume replay, host balance."""
import numpy as np

from repro.data import PKGDataPipeline, SyntheticCorpus


def _pipe(partitioner="pkg", host_id=0, n_hosts=4, seed=0):
    return PKGDataPipeline(
        batch_size=4,
        seq_len=128,
        vocab_size=1000,
        n_hosts=n_hosts,
        host_id=host_id,
        partitioner=partitioner,
        corpus=SyntheticCorpus(1000, n_keys=512, zipf_z=1.3, seed=seed),
        seed=seed,
    )


def test_batch_shapes_and_shift():
    p = _pipe()
    b = next(p)
    assert b["tokens"].shape == (4, 128) and b["labels"].shape == (4, 128)
    # labels are tokens shifted by one within the packed stream
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_deterministic_across_instances():
    a = [next(_pipe()) for _ in range(1)][0]
    b = [next(_pipe()) for _ in range(1)][0]
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_resume_replays_exactly():
    p1 = _pipe()
    for _ in range(3):
        next(p1)
    state = p1.state()
    expected = [next(p1) for _ in range(3)]

    p2 = _pipe()
    p2.load_state(state)
    got = [next(p2) for _ in range(3)]
    for e, g in zip(expected, got):
        np.testing.assert_array_equal(e["tokens"], g["tokens"])
        np.testing.assert_array_equal(e["labels"], g["labels"])


def test_pkg_balances_hosts_better_than_kg():
    """Token-weighted host loads: PKG imbalance << KG under key skew."""

    def run(partitioner):
        p = _pipe(partitioner=partitioner, seed=3)
        for _ in range(40):
            next(p)
        loads = p.host_loads().astype(float)
        if partitioner == "kg":  # kg doesn't track loads; recompute from route
            loads = np.zeros(4)
            q = _pipe(partitioner="kg", seed=3)
            for i in range(200):
                keys, docs = q.corpus.chunk(i)
                lens = np.array([len(d) for d in docs])
                hosts = q._route(keys, lens)
                np.add.at(loads, hosts, lens)
        return (loads.max() - loads.mean()) / max(loads.mean(), 1)

    pkg = run("pkg")
    kg = run("kg")
    assert pkg < 0.02, pkg
    assert pkg < kg / 3, (pkg, kg)


def test_all_hosts_union_covers_stream():
    """Across hosts, every document lands exactly once (no loss, no dup)."""
    pipes = [_pipe(host_id=h, seed=9) for h in range(4)]
    corpus = SyntheticCorpus(1000, n_keys=512, zipf_z=1.3, seed=9)
    keys, docs = corpus.chunk(0)
    lens = np.array([len(d) for d in docs])
    routes = [p._route(keys, lens) for p in pipes]
    for r in routes[1:]:
        np.testing.assert_array_equal(routes[0], r)  # same routing everywhere
    counts = np.bincount(routes[0], minlength=4)
    assert counts.sum() == len(keys)
