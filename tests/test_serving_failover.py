"""Overload control and replica failure at the serving edge (DESIGN.md §8):
drain invariants across every registered host policy, bounded queues +
shedding, kill/revive schedules with deterministic replay, and the
metrics/accounting bugfix sweep (imbalance_series short streams, strict
ledger release)."""
import numpy as np
import pytest

from repro.core.metrics import (
    avg_imbalance_fraction,
    imbalance_series,
    tenant_imbalance_report,
)
from repro.core.routing import LoadLedger, host_policy_names, make_policy
from repro.core.streams import zipf_stream
from repro.serving import PolicyScheduler, simulate_serving

HOST = host_policy_names()


def _sched(name, n, **kw):
    return PolicyScheduler(make_policy(name, n, d=2, seed=0, **kw))


# --- drain invariants across every registered host policy -------------------


@pytest.mark.parametrize("name", HOST)
def test_drain_invariants(name):
    """completed + shed == m, ledger exactly zero post-drain, makespan covers
    the last admitted arrival — for every policy in the registry."""
    keys = zipf_stream(4_000, 300, 1.3, seed=0)
    n, util = 10, 0.9
    sched = _sched(name, n)
    res = simulate_serving(sched, keys, utilization=util, queue_bound=16)
    m = len(keys)
    assert res.completed + res.shed == m
    assert sched.loads.sum() == 0.0
    assert (sched.loads == 0.0).all()
    dt = 1.0 / (util * n)
    admitted = np.flatnonzero(~res.shed_mask)
    assert res.makespan >= admitted[-1] * dt
    done = res.latency[~np.isnan(res.latency)]
    assert len(done) == res.completed
    assert (done >= 0).all()
    # percentiles are ordered and positive
    assert 0 < res.latency_p50 <= res.latency_p99 <= res.latency_p999


@pytest.mark.parametrize("name", HOST)
def test_kill_drain_invariants_and_determinism(name):
    """A mid-stream kill loses nothing, keeps the ledger clean, never routes
    to the dead replica afterwards, and replays deterministically."""
    keys = zipf_stream(5_000, 400, 1.4, seed=1)
    n, util = 12, 0.8
    dt = 1.0 / (util * n)
    t_kill = 2_500 * dt

    def run():
        sched = _sched(name, n)
        res = simulate_serving(
            sched, keys, utilization=util, kill_schedule=[(t_kill, 4)]
        )
        assert sched.loads.sum() == 0.0
        return res

    res, res2 = run(), run()
    assert res.completed == len(keys)  # zero lost completions, no shedding
    assert res.shed == 0
    assert not (res.assign[2_501:] == 4).any()
    # deterministic replay of the kill schedule
    np.testing.assert_array_equal(res.assign, res2.assign)
    np.testing.assert_array_equal(res.latency, res2.latency)
    np.testing.assert_array_equal(res.shed_mask, res2.shed_mask)
    assert res.requeued == res2.requeued


# --- overload: bounded queues, shedding, latency ----------------------------


def test_shedding_bounds_latency_under_overload():
    """utilization > 1 with a queue bound: the surplus is shed, per-request
    latency is structurally clamped at (bound x max cost), and the
    completed/shed split accounts for every request."""
    keys = zipf_stream(6_000, 500, 1.2, seed=2)
    sched = _sched("w_choices", 8)
    res = simulate_serving(sched, keys, utilization=1.5, queue_bound=4)
    assert res.shed > 0
    assert res.completed + res.shed == len(keys)
    # an admitted unit-cost request waits behind at most 4 predecessors
    assert np.nanmax(res.latency) <= 5.0 + 1e-9
    assert res.latency_p99 <= 5.0 + 1e-9
    # balanced policy sheds roughly the true surplus (1 - 1/1.5 ~ 1/3)
    assert res.shed / len(keys) < 0.5


def test_overload_without_bound_warns():
    keys = np.arange(500)
    with pytest.warns(RuntimeWarning, match="diverge"):
        simulate_serving(_sched("rr", 4), keys, utilization=1.2)


def test_queue_bound_validation():
    with pytest.raises(ValueError, match="queue_bound"):
        simulate_serving(_sched("rr", 4), np.arange(10), queue_bound=0)


def test_kill_schedule_requires_ledger():
    class Bare:  # classic route/complete/loads scheduler, no LoadLedger
        loads = np.zeros(4)

        def route(self, k, c=1.0):
            return 0

        def complete(self, r, c=1.0):
            pass

    with pytest.raises(ValueError, match="LoadLedger"):
        simulate_serving(Bare(), np.arange(10), kill_schedule=[(1.0, 0)])


def test_shed_requests_do_not_touch_caches_or_fanout():
    """A shed request is never served: it must not warm a cache or count
    toward session fanout."""
    keys = np.zeros(100, dtype=np.int64)  # one session, rr sprays it
    sched = _sched("rr", 4)
    res = simulate_serving(sched, keys, utilization=3.0, queue_bound=1)
    admitted = ~res.shed_mask
    assert res.session_fanout_max <= len(set(res.assign[admitted].tolist()))
    assert not res.hit[res.shed_mask].any()


# --- revival / cache re-warm -------------------------------------------------


def test_revive_rejoins_with_cold_cache():
    """Sticky KG: the killed replica's sessions come back after revival
    (same hash), but its first hits are misses — the cache was wiped."""
    keys = zipf_stream(8_000, 200, 1.2, seed=3)
    n, util = 8, 0.7
    dt = 1.0 / (util * n)
    t_kill, t_revive = 3_000 * dt, 4_000 * dt
    sched = _sched("kg", n)
    res = simulate_serving(
        sched, keys, utilization=util, cache_capacity=64,
        kill_schedule=[(t_kill, 2)], revive_schedule=[(t_revive, 2)],
    )
    assert res.completed == len(keys)
    mid = (res.assign[3_001:4_000] == 2)
    assert not mid.any()  # dead window: nothing lands on 2
    back = res.assign[4_001:] == 2
    assert back.any()  # revived: sticky keys return
    # the first post-revival request of a session on the revived replica
    # cannot hit (cache wiped at kill)
    first_back = np.flatnonzero(res.assign == 2)
    first_back = first_back[first_back > 4_000][0]
    assert not res.hit[first_back]


# --- live-mask + strict accounting at the ledger level ----------------------


def test_ledger_kill_revive_bookkeeping():
    led = LoadLedger(4)
    assert led.live_mask() is None  # all-alive fast path
    led.kill(1)
    led.kill(2)
    assert led.any_dead
    np.testing.assert_array_equal(led.live_mask(), [True, False, False, True])
    led.revive(1)
    np.testing.assert_array_equal(led.live_mask(), [True, True, False, True])
    led.revive(2)
    assert led.live_mask() is None
    # killing everything is rejected before the mask goes empty
    led.kill(0), led.kill(1), led.kill(2)
    with pytest.raises(ValueError, match="last live replica"):
        led.kill(3)
    assert led.alive[3]


def test_ledger_imbalance_over_live_replicas_only():
    led = LoadLedger(4)
    for r, c in [(0, 8.0), (1, 4.0), (2, 2.0), (3, 2.0)]:
        led.acquire(r, c)
    led.kill(0)  # the max-loaded replica is dead: not headroom, not max
    assert led.imbalance() == pytest.approx(4.0 - (4.0 + 2.0 + 2.0) / 3)


def test_strict_ledger_raises_on_over_release():
    led = LoadLedger(2, strict=True)
    led.acquire(0, 2.0)
    led.release(0, 2.0)  # exact: fine
    with pytest.raises(ValueError, match="over-release"):
        led.release(0, 1.0)  # double complete
    # non-strict keeps the legacy clamp-at-zero behavior
    loose = LoadLedger(2)
    loose.acquire(0, 1.0)
    loose.release(0, 5.0)
    assert loose.loads[0] == 0.0


@pytest.mark.parametrize("name", HOST)
def test_policies_never_route_to_dead_replicas(name):
    """decide() under a live mask returns live replicas only, for every
    registered host policy and every single-dead-replica mask."""
    n = 8
    pol = make_policy(name, n, d=2, seed=0)
    pol.reset()
    rng = np.random.default_rng(0)
    loads = rng.random(n)
    for dead in range(n):
        alive = np.ones(n, dtype=bool)
        alive[dead] = False
        for k in range(50):
            assert pol.decide(int(k), loads, alive) != dead


def test_kg_failover_redistributes_not_piles():
    """KG's rehash chain scatters a dead replica's keys over many survivors
    (consistent-hash-style), instead of dumping them all on one."""
    n = 16
    pol = make_policy("kg", n, seed=0)
    loads = np.zeros(n)
    keys = [k for k in range(2_000)
            if pol.decide(k, loads) == 5]  # keys sticky to replica 5
    assert len(keys) > 30
    alive = np.ones(n, dtype=bool)
    alive[5] = False
    moved = {pol.decide(k, loads, alive) for k in keys}
    assert 5 not in moved
    assert len(moved) > n // 2  # spread, not piled
    # and the chain is deterministic
    assert [pol.decide(k, loads, alive) for k in keys[:20]] == \
        [pol.decide(k, loads, alive) for k in keys[:20]]


def test_potc_all_candidates_dead_spills_to_live_argmin():
    n = 6
    pol = make_policy("potc", n, d=2, seed=0)
    loads = np.array([5.0, 4.0, 3.0, 2.0, 1.0, 0.0])
    for k in range(100):
        c = pol.candidates(k)
        alive = np.ones(n, dtype=bool)
        alive[c] = False  # kill exactly the candidates
        if not alive.any():
            continue
        got = pol.decide(k, loads, alive)
        expect = int(np.argmin(np.where(alive, loads, np.inf)))
        assert got == expect


def test_rr_skips_dead_and_stays_uniform():
    n = 6
    pol = make_policy("rr", n, seed=0)
    pol.reset()
    alive = np.ones(n, dtype=bool)
    alive[[1, 4]] = False
    out = [pol.decide(0, np.zeros(n), alive) for _ in range(400)]
    counts = np.bincount(out, minlength=n)
    assert counts[1] == 0 and counts[4] == 0
    live = counts[alive]
    assert live.max() - live.min() <= 1  # uniform over the live set


# --- metrics accounting bugfixes (ISSUE satellite) ---------------------------


def test_imbalance_series_short_stream_no_t0_checkpoint():
    """m < n_checkpoints used to emit a spurious I(0)=0 sample at t=0 that
    diluted every mean over the series; the first checkpoint is now >= 1."""
    assign = np.zeros(50, dtype=np.int64)  # all on worker 0 of 2
    ts, series = imbalance_series(assign, 2, n_checkpoints=100)
    assert ts[0] >= 1
    assert len(ts) == 50  # checkpoints 1..50, no duplicate 0
    # pinned: I(t) = t - t/2 = t/2, mean over t=1..50 is 25.5/2
    assert avg_imbalance_fraction(assign, 2) == pytest.approx(
        (25.5 / 2) / 50
    )


def test_imbalance_series_empty_stream():
    ts, series = imbalance_series(np.zeros(0, dtype=np.int64), 4)
    assert len(ts) == 0 and len(series) == 0
    assert np.isnan(avg_imbalance_fraction(np.zeros(0, dtype=np.int64), 4))


def test_tenant_report_small_tenant_not_diluted():
    """A tiny tenant (m < n_checkpoints) is scored without the phantom
    I(0)=0 checkpoint: an all-on-one-replica tenant of 20 messages now
    reports mean I(t)/t == (1 - 1/n) exactly, which breaks any sane SLO."""
    m_small = 20
    assign = np.zeros(m_small, dtype=np.int64)
    tenants = np.zeros(m_small, dtype=np.int64)
    rep = tenant_imbalance_report(assign, tenants, 4, slo=0.05,
                                  n_checkpoints=50)
    t0 = rep["tenants"][0]
    assert t0["violated"]
    assert t0["mean_imbalance_fraction"] == pytest.approx(1 - 1 / 4)
    assert t0["checkpoint_violations"] == t0["checkpoints"]
