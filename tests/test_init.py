"""Init-scale invariants: gradient norms must not compound with depth
(regression test for the 3-D fan-in bug found during the 100M run)."""
import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data import PKGDataPipeline, SyntheticCorpus
from repro.models import init_params
from repro.models.transformer import loss_fn


def _cfg(L):
    return ModelConfig(
        name=f"probe-{L}", family="dense", n_layers=L, d_model=256,
        n_heads=8, n_kv_heads=4, head_dim=32, d_ff=512, vocab_size=4096,
        attn_pattern=("global",), tie_embeddings=True, attn_q_block=64,
    )


def _gnorm(L):
    cfg = _cfg(L)
    params = init_params(cfg, jax.random.PRNGKey(0))
    pipe = PKGDataPipeline(batch_size=2, seq_len=64, vocab_size=cfg.vocab_size,
                           corpus=SyntheticCorpus(cfg.vocab_size, n_keys=512, mean_len=64, seed=1),
                           seed=1)
    batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
    (_, _), g = jax.jit(jax.value_and_grad(lambda p, b: loss_fn(p, b, cfg), has_aux=True))(
        params, batch
    )
    return float(jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                              for x in jax.tree_util.tree_leaves(g))))


def test_gradient_norm_stable_with_depth():
    g2, g12 = _gnorm(2), _gnorm(12)
    assert g12 < 30 * g2, (g2, g12)  # exponential blowup would be >1000x
    assert g12 < 100, (g2, g12)


def test_attention_init_std_uses_d_model_fan_in():
    cfg = _cfg(2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    wq = np.asarray(params["superblocks"][0]["mix"]["wq"])
    assert abs(wq.std() - 1 / np.sqrt(cfg.d_model)) < 0.2 / np.sqrt(cfg.d_model)
    wo = np.asarray(params["superblocks"][0]["mix"]["wo"])
    assert abs(wo.std() - 1 / np.sqrt(cfg.n_heads * cfg.head_dim)) < 0.2 / np.sqrt(256)
