"""Local load estimation (paper §6.2 Q2): L ≈ G, probing doesn't help,
high disagreement coexists with good balance (Fig 6)."""
import numpy as np

from repro.core import (
    avg_imbalance_fraction,
    disagreement,
    simulate_sources,
    zipf_stream,
)

W = 8


def test_local_close_to_global_oracle():
    keys = zipf_stream(200_000, 20_000, 1.0, seed=1)
    g = avg_imbalance_fraction(simulate_sources(keys, W, 5, mode="global"), W)
    l = avg_imbalance_fraction(simulate_sources(keys, W, 5, mode="local"), W)
    # paper: "difference from the global variant is always less than one
    # order of magnitude"
    assert l < 10 * max(g, 1e-7) + 1e-5, (l, g)
    assert l < 1e-3


def test_robust_to_number_of_sources():
    keys = zipf_stream(100_000, 10_000, 1.0, seed=2)
    fracs = [
        avg_imbalance_fraction(simulate_sources(keys, W, s, mode="local"), W)
        for s in (1, 5, 10, 20)
    ]
    assert all(f < 1e-3 for f in fracs), fracs


def test_probing_does_not_improve():
    keys = zipf_stream(100_000, 10_000, 1.0, seed=3)
    l = avg_imbalance_fraction(simulate_sources(keys, W, 5, mode="local"), W)
    lp = avg_imbalance_fraction(
        simulate_sources(keys, W, 5, mode="probe", probe_period=1_000), W
    )
    # probing is at best comparable (paper: "does not improve load balance")
    assert lp > l / 10, (lp, l)


def test_high_disagreement_low_imbalance():
    """L and G make very different choices yet both balance well (Fig 6)."""
    keys = zipf_stream(100_000, 10_000, 0.8, seed=4)
    ag = simulate_sources(keys, W, 5, mode="global")
    al = simulate_sources(keys, W, 5, mode="local")
    dis = disagreement(ag, al)
    assert dis > 0.10, dis  # substantially different routing decisions
    assert avg_imbalance_fraction(al, W) < 1e-3


def test_skewed_sources_fig8():
    """KG-partitioned sources (graph out-degree skew) stay balanced."""
    from repro.core import graph_edge_stream

    src, dst = graph_edge_stream(100_000, 5_000, 20_000, seed=5)
    a = simulate_sources(dst, W, n_sources=10, mode="local", source_keys=src)
    assert avg_imbalance_fraction(a, W) < 2e-3
