"""Sharding plan correctness for every assigned arch at production mesh sizes
— validates divisibility of every parameter dim against its assigned mesh
axes WITHOUT compiling (fast; the dry-run is the full proof)."""
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.models.transformer import init_defs
from repro.parallel.spec import ParamDef, partition_specs

SIZES = {"pod": 2, "data": 16, "model": 16}


def _rules_for(arch, axes=("pod", "data", "model")):
    """Replicates make_plan's rule table without a concrete jax mesh."""
    cfg = get_config(arch)
    tp = SIZES["model"]
    kv_shard = cfg.n_kv_heads > 0 and cfg.n_kv_heads % tp == 0
    grp = cfg.n_heads // max(cfg.n_kv_heads, 1) if cfg.n_heads else 0
    head_tp = kv_shard or (grp > 0 and grp % tp == 0)
    experts_ep = cfg.n_experts > 0 and cfg.n_experts % tp == 0
    rnn_dim = cfg.rnn_width or (cfg.d_inner if cfg.ssm_state else 0)
    rnn_tp = rnn_dim > 0 and rnn_dim % tp == 0
    big = cfg.param_count() > 8e9
    fsdp = ("pod", "data") if big else ("data",)
    return cfg, {
        "embed": fsdp,
        "embed_attn": fsdp if head_tp else tuple(fsdp) + ("model",),
        "layers": None, "conv": None, "state": None,
        "ffn": None if experts_ep else "model",
        "vocab": "model",
        "heads": "model" if (cfg.n_heads and cfg.n_heads % tp == 0 and head_tp) else None,
        "kv": "model" if kv_shard else None,
        "experts": "model" if experts_ep else None,
        "rnn": "model" if rnn_tp else None,
        None: None,
    }


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_every_param_dim_divides_its_axes(arch):
    cfg, rules = _rules_for(arch)
    defs = init_defs(cfg)
    leaves = [
        l for l in
        __import__("jax").tree_util.tree_leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef))
        if isinstance(l, ParamDef)
    ]
    assert leaves
    for d in leaves:
        for dim, ax in zip(d.shape, d.axes):
            rule = rules.get(ax)
            if rule is None:
                continue
            axes = (rule,) if isinstance(rule, str) else rule
            tot = int(np.prod([SIZES[a] for a in axes]))
            assert dim % tot == 0, (arch, d.shape, d.axes, ax, rule)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_no_duplicate_mesh_axis_per_spec(arch):
    from jax.sharding import PartitionSpec as P
    import jax

    cfg, rules = _rules_for(arch)
    specs = partition_specs(init_defs(cfg), rules)
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=lambda s: isinstance(s, P))
    assert leaves
    for spec in leaves:
        assert isinstance(spec, P), spec
        flat = []
        for entry in spec:
            if entry is None:
                continue
            flat.extend(entry if isinstance(entry, tuple) else (entry,))
        assert len(flat) == len(set(flat)), (arch, spec)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_big_arch_state_fits_512_devices(arch):
    """Full train state (bf16-compute fp32-master AdamW) must fit 512 x 16GB."""
    cfg = get_config(arch)
    n = cfg.param_count()
    state_bytes = n * (4 + 4 + 4)  # fp32 master + m + v
    per_dev = state_bytes / 512
    assert per_dev < 12 * 1024**3, (arch, per_dev / 1e9)
