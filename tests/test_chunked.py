"""Chunked streaming engine (parallel/chunked_driver.py + core.traces +
streaming simulate_serving).

The load-bearing contract: routing a stream through the chunked driver is
BIT-EXACT to the one-shot scan for EVERY chunk size — including chunk sizes
that force a padded final chunk — because the carried (loads, Space-Saving
summary) tuple is exactly the scan state the one-shot path threads
internally.  One-shot references:

  pkg        -> kernels.pkg_route (same block size)
  d_choices  -> estimation.online_head_tables + adaptive_route_online
  w_choices  -> same with any_worker tables and w_mode=True

Plus: kill/revive invariance across chunk sizes, the Space-Saving carry
under drift, the epoch-aligned sharded differential, stream_chunks ==
generate() for every scenario type, trace-reader round-trips, the
compile-cache recompile warning, and streaming simulate_serving ==
array-mode aggregates.
"""
import os
import sys
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.estimation import online_head_tables
from repro.core.partitioners import (
    PARTITIONERS,
    d_choices_chunked_partition,
    pkg_chunked_partition,
    w_choices_chunked_partition,
)
from repro.core.streams import (
    DRIFT_SCENARIOS,
    SCALE_SCENARIOS,
    StreamSpec,
    drift_stream,
    stream_chunks,
    zipf_probs,
    zipf_stream,
)
from repro.core.streams import _sample_from_probs  # noqa: F401  (tested)
from repro.core.traces import (
    hash_raw_key,
    read_kv_trace,
    read_wikipedia_pagecounts,
    trace_chunks,
)
from repro.kernels.adaptive_route import adaptive_route_online
from repro.kernels.pkg_route import pkg_route
from repro.parallel.chunked_driver import (
    ChunkedRouter,
    ChunkedShardedRouter,
    clear_step_cache,
)
from repro.parallel.sharded_router import ref_sharded_route

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

W = 20
CAP = 64
DECAY = 512


def _pieces(keys, c):
    return [keys[lo : lo + c] for lo in range(0, len(keys), c)]


# ---------------------------------------------------------------------------
# bit-exactness vs the one-shot references
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("c", [1, 7, 128, 1000])
def test_pkg_chunked_eq_oneshot_any_chunk(c):
    """block=1 lets the one-shot reference cover stream lengths that pad the
    chunked driver's final chunk — the full {1, 7, 128, n} sweep."""
    n = 1000
    keys = zipf_stream(n, 300, 1.4, seed=1)
    ref = np.asarray(pkg_route(jnp.asarray(keys), W, d=2, seed=3,
                               chunk=n, block=1)[0])
    r = ChunkedRouter(W, "pkg", chunk=c, block=1, seed=3)
    got = r.route_stream(keys)
    assert np.array_equal(got, ref)
    # final loads == assignment histogram (pads never count)
    assert np.array_equal(r.loads, np.bincount(ref, minlength=W).astype(np.float32))


@pytest.mark.parametrize("c", [128, 256, 384, 1024])
def test_pkg_chunked_eq_oneshot_block128(c):
    n = 1024
    keys = zipf_stream(n, 300, 1.6, seed=2)
    ref = np.asarray(pkg_route(jnp.asarray(keys), W, d=2, seed=0,
                               chunk=n, block=128)[0])
    got = ChunkedRouter(W, "pkg", chunk=c, block=128, seed=0).route_stream(keys)
    assert np.array_equal(got, ref)


def _adaptive_ref(keys, n_workers, policy, block, d_max=8):
    w_mode = policy == "w_choices"
    kj = jnp.asarray(keys)
    tk, tn = online_head_tables(
        kj, block, CAP, n_workers, d=2, d_max=d_max,
        decay_period=DECAY, any_worker=w_mode,
    )
    lanes = 2 if w_mode else d_max
    return np.asarray(adaptive_route_online(
        kj, tk, tn, n_workers, d_base=2, d_max=lanes, seed=0,
        chunk=len(keys), block=block, w_mode=w_mode,
    )[0])


@pytest.mark.parametrize("policy", ["d_choices", "w_choices"])
@pytest.mark.parametrize("c", [128, 256, 1024])
def test_adaptive_chunked_eq_oneshot(policy, c):
    """The SS summary carried across chunks reproduces the one-shot online
    head tables: same emit-before-block, cond-decay, and update order."""
    n, n_workers = 1024, 50
    keys = zipf_stream(n, 400, 1.8, seed=4)
    ref = _adaptive_ref(keys, n_workers, policy, block=128)
    r = ChunkedRouter(n_workers, policy, chunk=c, block=128, seed=0,
                      d_max=8, ss_capacity=CAP, decay_period=DECAY)
    assert np.array_equal(r.route_stream(keys), ref)


@pytest.mark.parametrize("c", [1, 7, 128, 1000])
def test_adaptive_padding_any_chunk(c):
    """Padded final chunks cannot perturb the tracker, the histogram, or the
    water-fill: d_choices at block=1 over a pad-forcing length."""
    n, n_workers = 1000, 50
    keys = zipf_stream(n, 400, 1.8, seed=5)
    ref = _adaptive_ref(keys, n_workers, "d_choices", block=1)
    r = ChunkedRouter(n_workers, "d_choices", chunk=c, block=1, seed=0,
                      d_max=8, ss_capacity=CAP, decay_period=DECAY)
    assert np.array_equal(r.route_stream(keys), ref)


def test_ss_carry_handoff_under_drift():
    """Feeding a drifting stream in pieces (at block boundaries) hands the
    Space-Saving summary across route_stream calls without drift from the
    one-shot reference — the carry IS the tracker state."""
    n, n_workers = 2048, 50
    keys = drift_stream(n, 400, 1.8, seed=6, half_life=256)
    for policy in ("d_choices", "w_choices"):
        ref = _adaptive_ref(keys, n_workers, policy, block=128)
        r = ChunkedRouter(n_workers, policy, chunk=256, block=128, seed=0,
                          d_max=8, ss_capacity=CAP, decay_period=DECAY)
        got = np.concatenate([r.route_stream(p) for p in _pieces(keys, 512)])
        assert np.array_equal(got, ref), policy


def test_capacities_chunked_eq_oneshot():
    n = 1024
    cap = np.array([1.0 + (i % 4) for i in range(W)], np.float32)
    keys = zipf_stream(n, 300, 1.6, seed=7)
    ref = np.asarray(pkg_route(jnp.asarray(keys), W, d=2, seed=0, chunk=n,
                               block=128, capacities=jnp.asarray(cap))[0])
    got = ChunkedRouter(W, "pkg", chunk=256, block=128, seed=0,
                        capacities=cap).route_stream(keys)
    assert np.array_equal(got, ref)


# ---------------------------------------------------------------------------
# failure handling: kill/revive is chunk-size invariant
# ---------------------------------------------------------------------------


def test_kill_revive_chunk_invariance():
    """Killing at a chunk boundary (a multiple of both chunk sizes) yields
    identical assignments whatever the chunk size, and the dead worker is
    never chosen while masked."""
    n = 2048
    keys = zipf_stream(n, 300, 1.6, seed=8)
    outs = []
    for c in (128, 1024):
        r = ChunkedRouter(W, "pkg", chunk=c, block=128, seed=0)
        a1 = r.route_stream(keys[:1024])
        r.kill(7)
        a2 = r.route_stream(keys[1024:1536])
        r.revive(7)
        a3 = r.route_stream(keys[1536:])
        assert not (a2 == 7).any()
        # revive restored the pre-kill count: loads == live histogram again
        hist = np.bincount(np.concatenate([a1, a2, a3]), minlength=W)
        assert np.array_equal(r.loads.astype(np.int64), hist)
        outs.append(np.concatenate([a1, a2, a3]))
    assert np.array_equal(outs[0], outs[1])


# ---------------------------------------------------------------------------
# sharded epochs: chunk == load-sync epoch
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("w_mode", [False, True])
def test_sharded_epoch_differential(w_mode):
    S, P, B, n_workers, E = 4, 2, 32, 30, 5
    epoch = S * P * B
    n = E * epoch
    keys = zipf_stream(n, 200, 1.5, seed=9)
    if w_mode:
        from repro.core.estimation import W_SENTINEL
        from repro.core.partitioners import _head_flags

        flags = _head_flags(keys, n_workers, 2, None, 1024, 8)
        nc = np.where(flags != 0, np.int32(W_SENTINEL), np.int32(2))
        nc = nc.astype(np.int32)
    else:
        nc = None
    # ref layout: shard-major over the whole stream; chunked layout: epoch-
    # major ([epoch][shard][block]) — permute the stream so both routers see
    # identical (shard, epoch, block) cells
    ek = np.asarray(keys).reshape(E, S, P * B)
    ref_keys = ek.swapaxes(0, 1).reshape(-1)
    ref_nc = (
        None if nc is None
        else nc.reshape(E, S, P * B).swapaxes(0, 1).reshape(-1)
    )
    ref_a, ref_loads = ref_sharded_route(
        jnp.asarray(ref_keys),
        None if ref_nc is None else jnp.asarray(ref_nc),
        n_workers, d_max=2, seed=0, n_shards=S, sync_period=P, block=B,
        w_mode=w_mode,
    )
    ref_a = np.asarray(ref_a).reshape(S, E, P * B).swapaxes(0, 1)
    router = ChunkedShardedRouter(
        n_workers, d_max=2, n_shards=S, sync_period=P, block=B, seed=0,
        w_mode=w_mode,
    )
    for e in range(E):
        a = router.route_chunk(
            ek[e].reshape(-1),
            n_cand=None if nc is None else nc.reshape(E, -1)[e],
        )
        assert np.array_equal(a.reshape(S, P * B), ref_a[e]), e
    assert np.array_equal(router.loads, np.asarray(ref_loads))


# ---------------------------------------------------------------------------
# registry partitioners
# ---------------------------------------------------------------------------


def test_chunked_partitioners_registered():
    for name in ("pkg_chunked", "d_choices_chunked", "w_choices_chunked"):
        assert name in PARTITIONERS


def test_chunked_partitioner_matches_kernel():
    n = 1024
    keys = zipf_stream(n, 300, 1.6, seed=10)
    ref = np.asarray(pkg_route(jnp.asarray(keys), W, d=2, seed=0,
                               chunk=n, block=128)[0])
    a = np.asarray(pkg_chunked_partition(jnp.asarray(keys), W, d=2, seed=0,
                                         chunk=256, block=128))
    assert np.array_equal(a, ref)
    # adaptive variants agree with their own chunk-size sweep
    for fn in (d_choices_chunked_partition, w_choices_chunked_partition):
        a1 = np.asarray(fn(jnp.asarray(keys), 50, seed=0, chunk=256,
                           capacity=CAP, decay_period=DECAY))
        a2 = np.asarray(fn(jnp.asarray(keys), 50, seed=0, chunk=1024,
                           capacity=CAP, decay_period=DECAY))
        assert np.array_equal(a1, a2), fn.__name__


# ---------------------------------------------------------------------------
# streams: chunked sampling identities
# ---------------------------------------------------------------------------


def test_sample_from_probs_chunked_identity():
    """The bounded-chunk sampler draws the same rng sequence as one giant
    searchsorted, so outputs are bit-identical."""
    probs = zipf_probs(5000, 1.5)
    rng = np.random.default_rng(11)
    got = _sample_from_probs(probs, 100_000, rng)
    cdf = np.cumsum(probs)
    cdf[-1] = 1.0
    ref = np.searchsorted(
        cdf, np.random.default_rng(11).random(100_000), side="right"
    ).astype(np.int32)
    assert np.array_equal(got, ref)


@pytest.mark.parametrize("spec", [
    StreamSpec("zipf", n_msgs=30_000, n_keys=2000, z=1.5),
    StreamSpec("matched", n_msgs=30_000, n_keys=2000, p1=0.2),
    StreamSpec("ln", n_msgs=30_000, n_keys=2000, mu=1.789, sigma=2.366),
    SCALE_SCENARIOS["W50_z1.6"],
    DRIFT_SCENARIOS["churn_hl8"],
])
@pytest.mark.parametrize("chunk", [1000, 4096, 65536])
def test_stream_chunks_eq_generate(spec, chunk):
    ref = np.asarray(spec.generate(seed=12, scale=0.2))
    got = np.concatenate(list(stream_chunks(spec, chunk, seed=12, scale=0.2)))
    assert got.dtype in (np.int32, ref.dtype)
    assert np.array_equal(got.astype(ref.dtype), ref)


# ---------------------------------------------------------------------------
# trace readers
# ---------------------------------------------------------------------------


def test_wikipedia_reader_expands_counts(tmp_path):
    p = tmp_path / "pagecounts"
    p.write_text(
        "en Main_Page 3 12288\n"
        "malformed-line\n"
        "de Seite 1 4096\n"
        "fr Page -2 0\n"          # non-positive count: skipped
        "en Other notanint 0\n"   # malformed count: skipped
        "ja ページ 2 8192\n"
    )
    got = np.concatenate(list(read_wikipedia_pagecounts(p, chunk=4)))
    exp = np.asarray(
        [hash_raw_key("en Main_Page")] * 3
        + [hash_raw_key("de Seite")]
        + [hash_raw_key("ja ページ")] * 2,
        np.int32,
    )
    assert np.array_equal(got, exp)
    # count expansion off: one event per surviving line
    got1 = np.concatenate(
        list(read_wikipedia_pagecounts(p, chunk=4, expand_counts=False))
    )
    assert len(got1) == 3


def test_kv_reader_and_chunk_shapes(tmp_path):
    p = tmp_path / "trace.kv"
    lines = [f"key with spaces {i % 17}\t{i}\n" for i in range(1000)]
    p.write_text("".join(lines) + "\n\n")  # trailing blanks skipped
    chunks = list(read_kv_trace(p, chunk=256))
    assert [len(c) for c in chunks] == [256, 256, 256, 232]
    got = np.concatenate(chunks)
    exp = np.asarray(
        [hash_raw_key(f"key with spaces {i % 17}") for i in range(1000)],
        np.int32,
    )
    assert np.array_equal(got, exp)
    # dispatcher + chunk-size invariance
    alt = np.concatenate(list(trace_chunks(p, "kv", chunk=999)))
    assert np.array_equal(alt, exp)
    with pytest.raises(ValueError):
        trace_chunks(p, "nope")


def test_make_trace_fixture_roundtrip(tmp_path):
    sys.path.insert(0, ROOT)
    try:
        from tools.make_trace import synth_events, write_trace_fixture
    finally:
        sys.path.remove(ROOT)
    idx = synth_events(5000, n_keys=300, seed=13)
    for fmt, key_fmt in (("wikipedia", "en Page_{}"), ("kv", "word_{}")):
        p = write_trace_fixture(tmp_path / f"t.{fmt}", fmt, 5000,
                                n_keys=300, seed=13)
        got = np.concatenate(list(trace_chunks(p, fmt, chunk=512)))
        exp = np.asarray([hash_raw_key(key_fmt.format(i)) for i in idx],
                         np.int32)
        assert np.array_equal(got, exp), fmt


# ---------------------------------------------------------------------------
# compile-cache behaviour
# ---------------------------------------------------------------------------


def test_recompile_warning_on_new_chunk_shape():
    clear_step_cache()
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("error")  # first shape: no warning
            ChunkedRouter(W, "pkg", chunk=256, block=128, seed=0)
        with pytest.warns(UserWarning, match="new chunk step"):
            ChunkedRouter(W, "pkg", chunk=512, block=128, seed=0)
        with warnings.catch_warnings():
            warnings.simplefilter("error")  # cached shape: silent
            ChunkedRouter(W, "pkg", chunk=512, block=128, seed=0)
    finally:
        clear_step_cache()


# ---------------------------------------------------------------------------
# streaming simulator
# ---------------------------------------------------------------------------


def _sim_pair(mk_sched, keys, piece, **kw):
    from repro.serving.sim import simulate_serving

    def chunks():
        for lo in range(0, len(keys), piece):
            yield keys[lo : lo + piece]

    a = simulate_serving(mk_sched(), keys, **kw)
    s = simulate_serving(mk_sched(), chunks(), **kw)
    return a, s


@pytest.mark.parametrize("kw", [
    dict(sample_every=512),
    dict(sample_every=512, utilization=1.3, queue_bound=4),
    dict(sample_every=512, kill_schedule=[(200.0, 3)],
         revive_schedule=[(900.0, 3)]),
])
def test_sim_streaming_eq_array(kw):
    from repro.serving.scheduler import PoTCScheduler

    keys = zipf_stream(20_000, 500, 1.4, seed=14)
    a, s = _sim_pair(lambda: PoTCScheduler(16, seed=1), np.asarray(keys),
                     1777, **kw)
    assert a.completed == s.completed
    assert a.shed == s.shed and a.requeued == s.requeued
    assert a.hit_rate == s.hit_rate
    assert a.makespan == s.makespan
    assert a.peak_outstanding == s.peak_outstanding
    assert a.session_fanout_max == s.session_fanout_max
    assert np.array_equal(a.assign_hist, s.assign_hist)
    assert np.array_equal(
        a.assign_hist, np.bincount(a.assign, minlength=len(a.assign_hist))
    )
    la = np.sort(a.latency[~np.isnan(a.latency)])
    assert np.array_equal(la, s.latency)  # reservoir not hit at this scale
    assert a.latency_p50 == s.latency_p50 and a.latency_p99 == s.latency_p99
    assert np.array_equal(a.sample_imbalance, s.sample_imbalance)
    assert len(s.assign) == 0 and len(s.shed_mask) == 0


def test_sim_streaming_guards():
    from repro.serving.scheduler import PoTCScheduler
    from repro.serving.sim import simulate_serving

    keys = np.zeros(10, np.int32)
    with pytest.raises(ValueError, match="costs"):
        simulate_serving(PoTCScheduler(4), iter([keys]), costs=np.ones(10))
    with pytest.raises(ValueError, match="tenants"):
        simulate_serving(PoTCScheduler(4), iter([keys]), tenants=[0] * 10)
    r = simulate_serving(PoTCScheduler(4), iter([]))
    assert r.completed == 0 and r.hit_rate == 0.0


# ---------------------------------------------------------------------------
# the 1e7-event nightly tier
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_trace_scale_1e7_tier():
    """1e7 events / 1e5 keys streamed through the chunked driver: carried
    state stays constant-size, every event lands exactly once, and the
    stream never materializes (generator in, histogram out)."""
    events, n_keys = 10_000_000, 100_000
    spec = StreamSpec("tier", n_msgs=events, n_keys=n_keys, z=1.4)
    r = ChunkedRouter(32, "pkg", chunk=8192, block=128, seed=0)
    state0 = r.state_bytes()
    hist = np.zeros(32, np.int64)

    def on_chunk(a):
        hist[:] = hist + np.bincount(a, minlength=32)

    n = r.route_stream(spec.stream_chunks(8192, seed=0), on_chunk=on_chunk)
    assert n == events
    assert int(hist.sum()) == events
    assert r.state_bytes() == state0  # flat: carry never grows
    assert np.array_equal(r.loads.astype(np.int64), hist)
    # balance sanity: at z=1.4 the head key is ~p1=32% of the stream, so
    # single-choice hashing floors at ~p1 - 1/n while PKG's key splitting
    # halves the head — assert we land at the split-head floor, not the
    # single-choice one
    p1 = float(zipf_probs(n_keys, 1.4)[0])
    frac = float(hist.max() - hist.mean()) / events
    assert frac < 0.6 * p1
    assert frac > 0.0  # not a degenerate all-one-worker histogram
